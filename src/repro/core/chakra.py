"""Chakra-style workload graph (the paper's interchange format).

Node types follow the Chakra ET schema semantics (MLCommons): COMP nodes for
compute kernels, COMM_COLL for collectives, COMM_SEND/COMM_RECV for expanded
point-to-point messages, MEM for host/staging ops.  Two edge kinds:

  * deps      -- *true data dependencies* (SSA operands from the compiler IR;
                 the property that sets Flint apart from CUDA-API capture, SS2.2)
  * ctrl_deps -- scheduling/synchronization edges.  Passes may add/remove
                 these (e.g. FSDP sync injection / AllGather reordering,
                 Fig 3b) but never touch data deps.

Serialized as JSON ET (one file per rank) so external Chakra consumers
(ASTRA-sim, Genie, ...) stay pluggable (P1).

Derived structure (topo order, consumer lists, the costmodel's CompiledGraph)
is memoized on the Graph under a cheap edit token — (n_nodes, n_dep_edges,
n_ctrl_edges, numeric-attr checksum) — so repeated simulate()/pass queries
don't rebuild O(N+E) state.  The token catches every mutation made through
``add()``, every in-place edge edit that changes an edge count, and every
in-place edit of the numeric attrs the cost model reads (flops, bytes,
comm_bytes, out_bytes) or of the attr-key set (hash-exact per value and
position; collisions are astronomically unlikely, not adversarial-proof).
Code that rewrites edge *targets* while keeping counts identical, or that
edits non-numeric attr *values* in place (comm_kind, group contents), must
call ``invalidate_caches()`` — though the codebase idiom is to ``copy()``
before editing (all passes do).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional

COMP = "COMP"
COMM_COLL = "COMM_COLL"
COMM_SEND = "COMM_SEND"
COMM_RECV = "COMM_RECV"
MEM = "MEM"


@dataclasses.dataclass
class Node:
    id: int
    name: str
    type: str
    deps: List[int] = dataclasses.field(default_factory=list)
    ctrl_deps: List[int] = dataclasses.field(default_factory=list)
    attrs: Dict = dataclasses.field(default_factory=dict)

    @property
    def all_deps(self) -> List[int]:
        return self.deps + self.ctrl_deps

    def fingerprint(self) -> str:
        """Stable cross-format identity: name plus op class.  The trace
        subsystem (repro.trace.align) re-identifies nodes in an ingested
        timeline by this string; nodes sharing a fingerprint are
        disambiguated by program order, so it must not depend on node id
        or on attrs a measured trace cannot reproduce."""
        return f"{self.name}|{self.type}"


class Graph:
    def __init__(self, meta: Optional[Dict] = None):
        self.nodes: List[Node] = []
        self.meta: Dict = meta or {}
        self._cache: Dict = {}

    # -- derived-structure cache --------------------------------------------
    def _token(self):
        """Cheap edit token guarding memoized derived structure: node/edge
        counts plus a position-sensitive hash of the numeric attrs the cost
        model reads, so in-place edits like ``g.node(i).attrs["flops"] = x``
        — including swaps between nodes and tiny deltas next to huge values
        (no float-sum absorption) — invalidate too."""
        nodes = self.nodes
        attrs_h = hash(tuple([
            hash((a.get("flops", 0.0), a.get("bytes", 0.0),
                  a.get("comm_bytes", 0.0), a.get("out_bytes", 0.0), len(a)))
            for a in [n.attrs for n in nodes]]))
        return (len(nodes), sum([len(n.deps) for n in nodes]),
                sum([len(n.ctrl_deps) for n in nodes]), attrs_h)

    def invalidate_caches(self):
        """Drop memoized topo order / consumers / compiled form.  Needed only
        after in-place edge retargeting that preserves edge counts."""
        self._cache = {}

    def _cached(self, key: str, build):
        tok = self._token()
        hit = self._cache.get(key)
        if hit is not None and hit[0] == tok:
            return hit[1]
        val = build()
        self._cache[key] = (tok, val)
        return val

    # -- construction -------------------------------------------------------
    def add(self, name: str, type: str, deps: Iterable[int] = (),
            ctrl_deps: Iterable[int] = (), **attrs) -> int:
        nid = len(self.nodes)
        self.nodes.append(Node(nid, name, type, list(deps), list(ctrl_deps),
                               attrs))
        return nid

    def node(self, nid: int) -> Node:
        return self.nodes[nid]

    def __len__(self):
        return len(self.nodes)

    # -- queries ------------------------------------------------------------
    def by_type(self, t: str) -> List[Node]:
        return [n for n in self.nodes if n.type == t]

    def consumers(self) -> Dict[int, List[int]]:
        """dep id -> consumer ids (duplicates kept when a consumer lists the
        same dep in both edge kinds).  Memoized; treat the result as
        read-only."""
        return self._cached("consumers", self._build_consumers)

    def _build_consumers(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for d in n.deps:
                out[d].append(n.id)
            for d in n.ctrl_deps:
                out[d].append(n.id)
        return out

    def topo_order(self) -> List[int]:
        """Kahn order with LIFO tie-breaking.  Memoized; treat the result as
        read-only."""
        return self._cached("topo", self._build_topo_order)

    def _build_topo_order(self) -> List[int]:
        n_nodes = len(self.nodes)
        dense = all(n.id == i for i, n in enumerate(self.nodes))
        if dense:
            indeg = [0] * n_nodes
            cons: List[List[int]] = [[] for _ in range(n_nodes)]  # dedup'd
        else:                       # hand-built graphs with arbitrary ids
            indeg = {n.id: 0 for n in self.nodes}
            cons = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            ad = n.deps + n.ctrl_deps
            if len(ad) > 1:
                ad = set(ad)
            indeg[n.id] = len(ad)
            for d in ad:
                cons[d].append(n.id)
        ready = [n.id for n in self.nodes if indeg[n.id] == 0]
        order: List[int] = []
        while ready:
            nid = ready.pop()
            order.append(nid)
            for c in cons[nid]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(order) != n_nodes:
            raise ValueError("graph has a cycle")
        return order

    def validate(self) -> bool:
        ids = {n.id for n in self.nodes}
        for n in self.nodes:
            for d in n.all_deps:
                if d not in ids or d == n.id:
                    raise ValueError(f"bad dep {d} of node {n.id}")
        self.topo_order()
        return True

    # -- stats ---------------------------------------------------------------
    def totals(self) -> Dict:
        flops = sum(n.attrs.get("flops", 0.0) for n in self.nodes)
        bytes_ = sum(n.attrs.get("bytes", 0.0) for n in self.nodes
                     if n.type == COMP)
        comm = {}
        for n in self.by_type(COMM_COLL):
            k = n.attrs.get("comm_kind", "?")
            comm.setdefault(k, [0, 0.0])
            comm[k][0] += 1
            comm[k][1] += n.attrs.get("comm_bytes", 0.0)
        return {"flops": flops, "comp_bytes": bytes_,
                "comm": {k: {"count": c, "bytes": b}
                         for k, (c, b) in comm.items()},
                "comm_bytes": sum(b for _, b in comm.values()),
                "n_nodes": len(self.nodes)}

    # -- serialization -------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "schema": "flint-chakra-et-v1",
            "meta": self.meta,
            "nodes": [dataclasses.asdict(n) for n in self.nodes],
        })

    @classmethod
    def from_json(cls, s: str) -> "Graph":
        d = json.loads(s)
        g = cls(d.get("meta", {}))
        for nd in d["nodes"]:
            g.nodes.append(Node(**nd))
        return g

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Graph":
        with open(path) as f:
            return cls.from_json(f.read())

    def copy(self) -> "Graph":
        g = Graph(dict(self.meta))
        for n in self.nodes:
            g.nodes.append(Node(n.id, n.name, n.type, list(n.deps),
                                list(n.ctrl_deps), dict(n.attrs)))
        return g
