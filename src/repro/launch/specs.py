"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every dry-run
cell — weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models.layers import abstract_from_specs, logical_axes_from_specs
from repro.models.model import Model
from repro.parallel.sharding import (activation_rules, batch_specs,
                                     param_rules, resolve_spec, tree_shardings)
from repro.train.optimizer import abstract_opt_state, opt_state_logical_axes
from repro.train.train_step import TrainState


def parallel_for_cell(cfg: ModelConfig, shape: ShapeConfig,
                      base: ParallelConfig = None) -> ParallelConfig:
    par = base or ParallelConfig()
    if shape.kind == "train":
        par = par.replace(remat="full")
    if shape.name == "long_500k":
        par = par.replace(seq_shard_cache=True)
    return par


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                parallel: ParallelConfig = None) -> Tuple:
    """Returns (abstract_args, in_shardings, model, parallel, donate) for the
    step function of this cell's kind."""
    parallel = parallel_for_cell(cfg, shape, parallel)
    model = Model(cfg)
    p_rules = param_rules(parallel)
    a_rules = activation_rules(parallel)

    pspecs = model.param_specs()
    params_abs = abstract_from_specs(pspecs)
    params_sh = tree_shardings(mesh, pspecs, p_rules)

    bspecs = batch_specs(cfg, shape, model)
    batch_abs = abstract_from_specs(bspecs)
    batch_sh = tree_shardings(mesh, bspecs, a_rules)

    if shape.kind == "train":
        opt_abs = abstract_opt_state(params_abs)
        opt_ax = opt_state_logical_axes(model.param_logical_axes())
        from jax.sharding import NamedSharding, PartitionSpec as P
        opt_sh = type(opt_abs)(
            step=NamedSharding(mesh, P()),
            mu=jax.tree_util.tree_map(
                lambda sh: sh, params_sh),
            nu=jax.tree_util.tree_map(lambda sh: sh, params_sh))
        state_abs = TrainState(params=params_abs, opt=opt_abs, err={})
        state_sh = TrainState(params=params_sh, opt=opt_sh, err={})
        return (state_abs, batch_abs), (state_sh, batch_sh), model, parallel, (0,)

    if shape.kind == "prefill":
        args = [params_abs, batch_abs["tokens"]]
        shard = [params_sh, batch_sh["tokens"]]
        if "memory" in batch_abs:
            args.append(batch_abs["memory"])
            shard.append(batch_sh["memory"])
        return tuple(args), tuple(shard), model, parallel, ()

    # decode: one token against a filled cache of shape.seq_len
    cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
    cache_abs = abstract_from_specs(cspecs)
    cache_sh = tree_shardings(mesh, cspecs, a_rules)
    args = (params_abs, batch_abs["token"], cache_abs)
    shard = (params_sh, batch_sh["token"], cache_sh)
    return args, shard, model, parallel, (2,)


def step_fn_for(model: Model, shape: ShapeConfig, parallel: ParallelConfig,
                mesh, opt_cfg=None):
    from repro.train.serve_step import make_decode_step, make_forward_step
    from repro.train.train_step import make_train_step
    from repro.train.optimizer import OptConfig
    if shape.kind == "train":
        return make_train_step(model, opt_cfg or OptConfig(), parallel, mesh)
    if shape.kind == "prefill":
        return make_forward_step(model, parallel, mesh)
    return make_decode_step(model, parallel, mesh)
