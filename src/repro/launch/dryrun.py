import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with 512 placeholder host devices, print memory_analysis()
and cost_analysis(), and persist the Flint capture summary for the roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every cell, both meshes
                                                 # (one subprocess per cell)
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

ARTIFACT_DIR = os.environ.get("FLINT_ARTIFACTS",
                              os.path.join(os.path.dirname(__file__),
                                           "..", "..", "..", "artifacts",
                                           "dryrun"))


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_graph: bool = False, quiet: bool = False,
             optimized: bool = False) -> dict:
    """optimized=False: paper-faithful baseline (TP+SP model axis, XLA
    attention accounting).  optimized=True: the hillclimbed configuration —
    ZeRO-3 model axis for train cells + Pallas-fused kernel accounting
    (EXPERIMENTS.md SSPerf)."""
    import jax
    from repro.configs.registry import (cell_applicable, get_config,
                                        get_shape)
    from repro.core.capture import capture_step
    from repro.core.costmodel.analytical import (model_flops_per_step,
                                                 roofline)
    from repro.configs.base import ParallelConfig, SystemConfig
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, step_fn_for

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_applicable(cfg, shape)
    mesh_tag = "multipod" if multi_pod else "singlepod"
    cell_id = f"{arch}__{shape_name}__{mesh_tag}"
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": why}
        _write(out_dir, cell_id, rec)
        if not quiet:
            print(f"[dryrun] {cell_id}: SKIPPED ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(len(mesh.devices.flat))
    base_par = None
    model_axis_size = mesh.shape.get("model", 1)
    if optimized and shape.kind == "train":
        # hillclimbed strategy (EXPERIMENTS.md SSPerf): ZeRO-3 over the model
        # axis beats TP for train shapes — except when the expert count
        # divides the model axis, where expert parallelism wins (dbrx).
        ep_capable = (cfg.num_experts > 0
                      and cfg.num_experts % model_axis_size == 0)
        if not ep_capable:
            base_par = ParallelConfig(model_axis="zero3")
    elif optimized and shape.kind in ("prefill", "decode"):
        # serving: keep weights resident (no per-step FSDP re-gather) when
        # the TP-sharded params fit comfortably next to the KV cache
        params_per_dev = cfg.param_count() * 2 / model_axis_size
        if params_per_dev < 12e9:
            base_par = ParallelConfig(fsdp=False)
    args, shardings, model, parallel, donate = input_specs(cfg, shape, mesh,
                                                           base_par)
    step = step_fn_for(model, shape, parallel, mesh)

    t0 = time.time()
    cap = capture_step(step, args, shardings, mesh,
                       meta={"arch": arch, "shape": shape_name,
                             "mesh": mesh_tag, "kind": shape.kind,
                             "optimized": optimized},
                       donate_argnums=donate, build_graph=save_graph)
    mf = model_flops_per_step(cfg, shape, n_dev)
    sysc = SystemConfig(chips=n_dev)
    rl = roofline(cap.summary, cap.cost_analysis, sysc, mf,
                  fused_kernels=optimized)

    rec = {
        "cell": cell_id, "status": "ok",
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "devices": n_dev, "kind": shape.kind,
        "t_lower_s": cap.meta["t_lower_s"], "t_compile_s": cap.meta["t_compile_s"],
        "memory_analysis": cap.memory_analysis,
        "cost_analysis": cap.cost_analysis,
        "summary": {k: v for k, v in cap.summary.items()
                    if k != "collectives"},
        "collectives_head": cap.summary["collectives"][:40],
        "roofline": rl.as_dict(),
    }
    _write(out_dir, cell_id, rec)
    if save_graph:
        cap.graph.save(os.path.join(out_dir, cell_id + ".chakra.json"))
    if not quiet:
        print(f"[dryrun] {cell_id}: OK  devices={n_dev} "
              f"compile={cap.meta['t_compile_s']:.1f}s")
        print(f"  memory_analysis: {cap.memory_analysis}")
        print(f"  cost_analysis(flops)={cap.cost_analysis.get('flops', 0):.3e} "
              f"bytes={cap.cost_analysis.get('bytes accessed', 0):.3e}")
        print(f"  flint: flops={cap.summary['parsed_flops']:.3e} "
              f"coll_bytes={cap.summary['comm_bytes']:.3e} "
              f"comm={ {k: v['count'] for k, v in cap.summary['comm'].items()} }")
        print(f"  roofline: compute={rl.compute_s*1e3:.3f}ms "
              f"memory={rl.memory_s*1e3:.3f}ms coll={rl.collective_s*1e3:.3f}ms "
              f"bound={rl.bound} useful={rl.useful_ratio:.2f}")
    return rec


def _write(out_dir, cell_id, rec):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def run_all(out_dir: str, meshes=("singlepod", "multipod"),
            archs=None, shapes=None, optimized: bool = False):
    """Run every cell in a subprocess (isolates failures + compile state)."""
    from repro.configs.registry import ARCH_NAMES
    from repro.configs.base import ALL_SHAPES
    archs = archs or ARCH_NAMES
    shapes = shapes or [s.name for s in ALL_SHAPES]
    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_tag in meshes:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", out_dir]
                if mesh_tag == "multipod":
                    cmd.append("--multi-pod")
                if optimized:
                    cmd.append("--optimized")
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=1800)
                dt = time.time() - t0
                cell = f"{arch}__{shape}__{mesh_tag}"
                if r.returncode != 0:
                    print(f"[dryrun] {cell}: FAILED ({dt:.0f}s)")
                    print(r.stdout[-2000:])
                    print(r.stderr[-3000:])
                    results.append({"cell": cell, "status": "failed"})
                else:
                    tail = [l for l in r.stdout.splitlines() if l.strip()]
                    print("\n".join(tail))
                    results.append({"cell": cell, "status": "done",
                                    "wall_s": dt})
    with open(os.path.join(out_dir, "_index.json"), "w") as f:
        json.dump(results, f, indent=1)
    n_bad = sum(1 for r in results if r["status"] == "failed")
    print(f"[dryrun] {len(results)} cells, {n_bad} failures")
    return n_bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--save-graph", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="hillclimbed config (zero3 train + fused kernels)")
    args = ap.parse_args()
    if args.all:
        sys.exit(1 if run_all(args.out, optimized=args.optimized) else 0)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                       save_graph=args.save_graph, optimized=args.optimized)
        sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
