"""Training driver: checkpointed, preemptible, fault-tolerant.

  python -m repro.launch.train --arch qwen3-8b --smoke --steps 200

Composes the fault-tolerance substrate (DESIGN.md SS7): atomic checkpoints
with keep-last-k, resume-from-latest with exact data replay, SIGTERM
preemption save, per-step straggler detection, and transient-failure retry.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--inject-fault-at", type=int, default=-1)
    args = ap.parse_args(argv)

    from repro.configs.base import ParallelConfig
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.train import (DataConfig, DataIterator, OptConfig, TrainState,
                             init_train_state, latest_step, make_train_step,
                             restore_checkpoint, save_checkpoint)
    from repro.train.fault import (FaultInjector, PreemptionHandler,
                                   SimulatedFault, StepTimer,
                                   StragglerMonitor, run_with_retry)
    from repro.train.optimizer import abstract_opt_state

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    par = ParallelConfig(remat="none" if args.smoke else "full",
                         microbatches=args.microbatches,
                         grad_compression=args.grad_compression)
    opt = OptConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch, memory_len=model.memory_len(),
                    d_model=cfg.d_model)

    step_fn = jax.jit(make_train_step(model, opt, par))
    state = init_train_state(model, jax.random.PRNGKey(0), par)
    start_step = 0

    ckpt_dir = args.ckpt_dir or os.path.join("checkpoints", cfg.name)
    if args.resume:
        last = latest_step(ckpt_dir)
        if last is not None:
            template = jax.tree_util.tree_map(lambda x: x, state)
            state, meta = restore_checkpoint(ckpt_dir, last, template)
            start_step = meta["step"]
            print(f"[train] resumed from step {start_step}")

    it = DataIterator(dc, start_step=start_step)
    preempt = PreemptionHandler().install()
    monitor = StragglerMonitor()
    injector = FaultInjector(
        fail_steps=(args.inject_fault_at,) if args.inject_fault_at >= 0 else ())

    metrics_log = []
    for step in range(start_step, args.steps):
        batch = next(it)

        def run(state=state, batch=batch, step=step):
            injector.check(step)
            return step_fn(state, batch)

        with StepTimer() as t:
            state, metrics = run_with_retry(
                run, retries=2,
                on_failure=lambda e, a: print(f"[train] step {step} failed "
                                              f"({e}); retry {a + 1}"))
            jax.block_until_ready(metrics["loss"])
        if monitor.record(step, t.duration):
            print(f"[train] straggler step {step}: {t.duration:.3f}s "
                  f"(median {monitor.median:.3f}s)")

        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {t.duration * 1e3:.0f}ms")
            metrics_log.append({"step": step, "loss": loss,
                                "t_ms": t.duration * 1e3})

        if (step + 1) % args.ckpt_every == 0 or preempt.should_stop:
            save_checkpoint(ckpt_dir, step + 1, state, keep=args.keep)
            if preempt.should_stop:
                print(f"[train] preempted; checkpointed at {step + 1}")
                break

    with open(os.path.join(ckpt_dir, "metrics.json"), "w") as f:
        json.dump(metrics_log, f, indent=1)
    print(f"[train] done; final loss "
          f"{metrics_log[-1]['loss'] if metrics_log else float('nan'):.4f}")
    return metrics_log


if __name__ == "__main__":
    main()
