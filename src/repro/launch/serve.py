"""Serving driver: batched prefill + decode with a KV/state cache.

  python -m repro.launch.serve --arch gemma3-4b --smoke --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    from repro.configs.base import ParallelConfig
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.train.serve_step import (make_decode_step, make_prefill_step,
                                        sample_token)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    par = ParallelConfig()
    cache_len = args.prompt_len + args.steps

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    memory = None
    ml = model.memory_len()
    if ml:
        memory = jax.random.normal(jax.random.PRNGKey(2),
                                   (args.batch, ml, cfg.d_model),
                                   jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(model, par, cache_len=cache_len))
    decode = jax.jit(make_decode_step(model, par), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt, memory)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{t_prefill * 1e3:.1f}ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    tok = sample_token(logits, rng, args.temperature)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.steps - 1):
        rng, k = jax.random.split(rng)
        logits, cache = decode(params, tok, cache)
        tok = sample_token(logits, k, args.temperature)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"[serve] decode {args.steps - 1} steps: {t_dec * 1e3:.1f}ms "
          f"({args.batch * (args.steps - 1) / max(t_dec, 1e-9):.0f} tok/s)")
    print(f"[serve] sample output ids: {toks[0, :16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
