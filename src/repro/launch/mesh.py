"""Production mesh definition (required by the multi-pod dry-run).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
