"""Entry point: ``python -m repro.obs report <metrics.json>``."""
from repro.obs.report import main

if __name__ == "__main__":
    raise SystemExit(main())
