"""Memory-timeline observability: schedule-resolved occupancy curves.

The engines' ``peak_bytes`` is a single scalar; this module reconstructs
the *whole curve* behind it — per-rank occupancy over time, resolved
against the actual simulated schedule (overlap, barrier stalls and MPMD
skew all move what is live when) — and makes it attributable:

Occupancy curves (``memory_timeline``)
    Each engine records ``(t, delta_bytes, nid)`` liveness events
    (``SimResult.mem_events``, kept with ``keep_timeline=True``): a
    tensor's ``out_bytes`` allocates at its producer's start and frees
    when its last data consumer finishes; a COMM node's ``comm_bytes``
    is a transient buffer live exactly for the span, tagged with the
    bitwise-complement node id ``~nid``.  The curve is evaluated at the
    elementary-interval breakpoints those events induce, with Shewchuk
    ``ExactSum`` accumulators per memory class (weights / activations /
    comm), so two identities hold **bit-exactly** at every breakpoint:

      (a) the class decomposition sums to the total occupancy — the
          union of the class accumulators' exact partials ``fsum``s to
          the very float the total accumulator reports;
      (b) the curve max equals the engine's ``peak_bytes`` to the last
          ulp (both are correctly-rounded sums of the same deltas,
          computed by independent walks).

Peak blame (``memory_blame``)
    The live tensors at the instant of peak.  A freed tensor's alloc and
    free deltas are exact negations, so the live tensors' bytes ``fsum``
    to the peak bit-exactly (``identity_ok``) — coverage is total, not
    best-effort.

Peak diff (``memory_diff``)
    Attributes ``b.peak - a.peak`` between two configs to memory classes
    (mirroring ``explain_diff``): per-run class terms are chosen so they
    sum *exactly* (in real arithmetic) to that run's float peak — class
    curve values plus an explicit ``(rounding)`` residual captured with
    ``ExactSum`` — so the signed term union ``fsum``s to the IEEE
    difference of the two peaks bit-exactly.

Classification: a node's ``mem_class`` attr wins; an all-gather's output
is ``weights`` (the FSDP gathered-parameter shape); any other
``out_bytes`` is ``activations``; ``~nid`` transients are ``comm``.

``memory_counters`` / ``export_memory_trace`` render the per-rank curves
as Chrome trace counter tracks; ``memory_timeline`` publishes per-rank
peak (and time-above-90%-capacity when ``hbm_bytes`` is given) as obs
gauges, which ``python -m repro.obs report --memory`` prints.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import chakra
from repro.core.costmodel.compiled import ExactSum
from repro.core.costmodel.simulator import ClusterSimResult, SimResult
from repro.obs import record as obs

MEM_CLASSES = ("weights", "activations", "comm")
_ROUNDING = "(rounding)"


def mem_class(graph: Optional[chakra.Graph], nid: int) -> str:
    """Memory class of one liveness event's tensor.  ``nid < 0`` is the
    transient comm buffer of node ``~nid``; for real tensors an explicit
    ``mem_class`` node attr wins, an all-gather's output counts as
    gathered weights, everything else is an activation."""
    if nid < 0:
        return "comm"
    if graph is None:
        return "activations"
    n = graph.node(nid)
    mc = n.attrs.get("mem_class")
    if mc:
        return str(mc)
    if (n.type == chakra.COMM_COLL
            and n.attrs.get("comm_kind") == "all-gather"):
        return "weights"
    return "activations"


def _mem_events_of(result: SimResult) -> List[Tuple]:
    if result.mem_events is None:
        raise ValueError("no mem_events recorded: re-run the simulation "
                         "with keep_timeline=True")
    return result.mem_events


@dataclass
class RankMemory:
    """One rank's occupancy curve over its scheduled timeline.

    ``times[i]`` are the elementary-interval breakpoints (every distinct
    event time); ``total[i]`` / ``by_class[c][i]`` the occupancy in force
    on ``[times[i], times[i+1])``.  ``peak_bytes`` replicates the
    engine's exact scan (floor 0.0), so ``identity_ok()`` certifies both
    bit-exact contracts: per-breakpoint class decomposition == total
    (checked during construction) and curve max == the engine's
    ``peak_bytes``."""
    rank: int
    times: List[float]
    total: List[float]
    by_class: Dict[str, List[float]]
    peak_bytes: float
    peak_time: float
    engine_peak: float
    hbm_bytes: Optional[float] = None
    events: List[Tuple] = field(repr=False, default_factory=list)
    _decomp_ok: bool = field(repr=False, default=True)

    def identity_ok(self) -> bool:
        return self._decomp_ok and self.peak_bytes == self.engine_peak

    def class_peak(self, cls: str) -> float:
        """Max occupancy of one memory class over the timeline (0.0 for a
        class the rank never allocates).  The per-class analogue of
        ``peak_bytes`` — e.g. ``class_peak("activations")`` is what the
        pipeline-schedule tests compare between GPipe (m stashed
        microbatches) and 1F1B (at most p)."""
        vs = self.by_class.get(cls)
        return max(vs) if vs else 0.0

    def class_at(self, t: float) -> Dict[str, float]:
        """Class occupancy in force at time ``t`` (step function)."""
        i = _step_index(self.times, t)
        if i < 0:
            return {c: 0.0 for c in self.by_class}
        return {c: vs[i] for c, vs in self.by_class.items()}

    def time_above(self, threshold: float) -> float:
        """Total seconds the occupancy strictly exceeds ``threshold``
        (the step function holds each value until the next breakpoint;
        the final value is a point in time, i.e. contributes nothing)."""
        s = 0.0
        for i in range(len(self.times) - 1):
            if self.total[i] > threshold:
                s += self.times[i + 1] - self.times[i]
        return s

    def utilization(self) -> Optional[float]:
        """peak / capacity, when ``hbm_bytes`` is known."""
        if not self.hbm_bytes:
            return None
        return self.peak_bytes / self.hbm_bytes


def _step_index(times: List[float], t: float) -> int:
    from bisect import bisect_right
    return bisect_right(times, t) - 1


def _build_rank(mem_events: List[Tuple], graph: Optional[chakra.Graph],
                rank: int, engine_peak: float,
                hbm_bytes: Optional[float]) -> RankMemory:
    """Sweep one rank's events into an exact occupancy curve."""
    events = sorted(mem_events)
    cls_of: Dict[int, str] = {}
    for _t, _d, nid in events:
        if nid not in cls_of:
            cls_of[nid] = mem_class(graph, nid)
    classes = [c for c in MEM_CLASSES if c in cls_of.values()]
    for c in sorted(set(cls_of.values())):
        if c not in classes:                     # custom mem_class attrs
            classes.append(c)

    accs = {c: ExactSum() for c in classes}
    total_acc = ExactSum()
    times: List[float] = []
    total: List[float] = []
    by_class: Dict[str, List[float]] = {c: [] for c in classes}
    decomp_ok = True
    peak = 0.0
    peak_time = 0.0
    i, m = 0, len(events)
    while i < m:
        t = events[i][0]
        while i < m and events[i][0] == t:
            _t, d, nid = events[i]
            accs[cls_of[nid]].add(d)
            total_acc.add(d)
            i += 1
        v = total_acc.value()
        times.append(t)
        total.append(v)
        for c in classes:
            by_class[c].append(accs[c].value())
        # identity (a): the union of the class accumulators' exact
        # partials is an exact representation of the same real sum the
        # total accumulator holds — fsum of the union must reproduce the
        # total's float bit-for-bit
        parts = [p for c in classes for p in accs[c].partials]
        if math.fsum(parts) != v:
            decomp_ok = False
        if v > peak:
            peak = v
            peak_time = t
    return RankMemory(rank=rank, times=times, total=total, by_class=by_class,
                      peak_bytes=peak, peak_time=peak_time,
                      engine_peak=engine_peak, hbm_bytes=hbm_bytes,
                      events=events, _decomp_ok=decomp_ok)


@dataclass
class MemoryTimeline:
    """Per-rank occupancy curves of one simulated result.  ``ranks`` maps
    rank id -> RankMemory (classes expanded for cluster results, so
    coalesced and naive runs produce identical per-rank curves)."""
    ranks: Dict[int, RankMemory]
    hbm_bytes: Optional[float] = None

    @property
    def peak_bytes(self) -> float:
        return max(rm.peak_bytes for rm in self.ranks.values())

    @property
    def peak_rank(self) -> int:
        pk = self.peak_bytes
        return min(r for r, rm in self.ranks.items() if rm.peak_bytes == pk)

    def identity_ok(self) -> bool:
        return all(rm.identity_ok() for rm in self.ranks.values())

    def table(self) -> str:
        cap = self.hbm_bytes
        lines = [f"peak occupancy {self.peak_bytes:.6e} B on rank "
                 f"{self.peak_rank} ({len(self.ranks)} ranks)"]
        for r in sorted(self.ranks):
            rm = self.ranks[r]
            at_peak = rm.class_at(rm.peak_time)
            cls = "  ".join(f"{c}={v:.3e}" for c, v in at_peak.items() if v)
            line = (f"  rank {r:<4} peak {rm.peak_bytes:>12.6e} B "
                    f"@ t={rm.peak_time:.3e}s   {cls}")
            if cap:
                hot = rm.time_above(0.9 * cap)
                line += (f"   {rm.peak_bytes / cap:6.1%} of HBM, "
                         f">90% for {hot:.3e}s")
            lines.append(line)
        return "\n".join(lines)


def memory_timeline(result, graph=None,
                    hbm_bytes: Optional[float] = None) -> MemoryTimeline:
    """Occupancy curves for a timeline-carrying ``SimResult`` /
    ``ClusterSimResult``.  ``graph`` (Graph / MPMDProgram / {rank: Graph})
    enriches tensor classes; ``hbm_bytes`` (per-rank capacity) enables
    utilization / time-above-90% reporting.  Publishes per-rank gauges
    when obs recording is on."""
    from repro.trace.export import graph_for_rank
    if isinstance(result, SimResult):
        rm = _build_rank(_mem_events_of(result), graph_for_rank(graph, 0),
                         0, result.peak_bytes, hbm_bytes)
        ranks = {0: rm}
    elif isinstance(result, ClusterSimResult):
        ranks = {}
        for r in range(result.n_ranks):
            rr = result.rank_result(r)
            ranks[r] = _build_rank(_mem_events_of(rr),
                                   graph_for_rank(graph, r), r,
                                   rr.peak_bytes, hbm_bytes)
    else:
        raise TypeError(f"expected SimResult or ClusterSimResult, "
                        f"got {type(result).__name__}")
    tl = MemoryTimeline(ranks=ranks, hbm_bytes=hbm_bytes)
    if obs.recording():
        for r, rm in tl.ranks.items():
            obs.gauge(f"memory.rank{r}.peak_bytes", rm.peak_bytes)
            if hbm_bytes:
                obs.gauge(f"memory.rank{r}.time_at_90pct",
                          rm.time_above(0.9 * hbm_bytes))
                obs.gauge(f"memory.rank{r}.hbm_bytes", float(hbm_bytes))
    return tl


# ------------------------------------------------------------------ blame

@dataclass
class LiveTensor:
    """One tensor live at the instant of peak."""
    nid: int                  # producing node id (< 0: comm buffer of ~nid)
    name: str
    cls: str
    bytes: float
    alloc_t: float
    free_t: Optional[float]   # None: never freed inside the step


@dataclass
class MemoryBlame:
    """The live-tensor set at one rank's occupancy peak.  The tensors'
    bytes ``fsum`` to ``peak_bytes`` bit-exactly (freed tensors' alloc and
    free deltas cancel exactly), so coverage is provably total."""
    rank: int
    peak_bytes: float
    peak_time: float
    tensors: List[LiveTensor]

    def total(self) -> float:
        return math.fsum(t.bytes for t in self.tensors)

    def identity_ok(self) -> bool:
        return self.total() == self.peak_bytes

    def by_class(self) -> Dict[str, float]:
        out: Dict[str, List[float]] = {}
        for t in self.tensors:
            out.setdefault(t.cls, []).append(t.bytes)
        return {c: math.fsum(vs) for c, vs in out.items()}

    def table(self, top: int = 12) -> str:
        lines = [f"rank {self.rank} peak {self.peak_bytes:.6e} B at "
                 f"t={self.peak_time:.3e}s — {len(self.tensors)} live "
                 f"tensors (top {min(top, len(self.tensors))}):"]
        for t in self.tensors[:top]:
            freed = "step end" if t.free_t is None else f"{t.free_t:.3e}s"
            lines.append(f"  {t.name:<28} {t.cls:<12} {t.bytes:>12.6e} B  "
                         f"[{t.alloc_t:.3e}s -> {freed}]")
        return "\n".join(lines)


def memory_blame(result, graph=None, rank: Optional[int] = None,
                 hbm_bytes: Optional[float] = None) -> MemoryBlame:
    """Live tensors at the instant of peak occupancy.  ``rank=None``
    picks the peak rank of a cluster result (rank 0 for a plain
    ``SimResult``).  Also accepts a ready-made ``MemoryTimeline``."""
    from repro.trace.export import graph_for_rank
    tl = (result if isinstance(result, MemoryTimeline)
          else memory_timeline(result, graph, hbm_bytes))
    r = tl.peak_rank if rank is None else rank
    rm = tl.ranks[r]
    g_r = graph_for_rank(graph, r)

    alloc: Dict[int, Tuple[float, float]] = {}    # nid -> (t, bytes)
    free: Dict[int, float] = {}
    pt = rm.peak_time
    for t, d, nid in rm.events:
        if t <= pt:
            if d > 0:
                alloc[nid] = (t, d)
            else:
                free[nid] = t
        elif d < 0 and nid in alloc:
            free.setdefault(nid, t)
    tensors = []
    for nid, (t0, b) in alloc.items():
        ft = free.get(nid)
        if ft is not None and ft <= pt:
            continue                               # freed before the peak
        if nid >= 0:
            name = g_r.node(nid).name if g_r is not None else f"n{nid}"
        else:
            base = (g_r.node(~nid).name if g_r is not None else f"n{~nid}")
            name = f"{base} (comm buffer)"
        tensors.append(LiveTensor(nid=nid, name=name,
                                  cls=mem_class(g_r, nid), bytes=b,
                                  alloc_t=t0, free_t=ft))
    tensors.sort(key=lambda t: (-t.bytes, t.nid))
    return MemoryBlame(rank=r, peak_bytes=rm.peak_bytes,
                       peak_time=rm.peak_time, tensors=tensors)


# ------------------------------------------------------------------- diff

def _peak_terms(rm: RankMemory) -> Dict[str, List[float]]:
    """Per-class terms that sum *exactly* (real arithmetic) to this
    rank's float ``peak_bytes``: the class curve values at the peak
    breakpoint plus an explicit rounding residual (``ExactSum`` of
    ``peak - sum(class values)``; empty when bytes sum exactly, e.g.
    integer-valued sizes)."""
    at_peak = rm.class_at(rm.peak_time) if rm.times else {}
    terms: Dict[str, List[float]] = {c: [v] for c, v in at_peak.items()}
    acc = ExactSum()
    acc.add(rm.peak_bytes)
    for v in at_peak.values():
        acc.add(-v)
    resid = [p for p in acc.partials if p]
    if resid:
        terms[_ROUNDING] = resid
    return terms


@dataclass
class MemoryDiff:
    """Attribution of ``b.peak - a.peak`` between two configs.

    ``by_class`` is a signed fsum reduction over both runs' peak terms,
    so ``total()`` equals ``delta_peak`` (the IEEE difference of the two
    float peaks) bit-exactly.  ``gained`` / ``lost`` name the largest
    tensors live at one peak but not the other — descriptive, not part
    of the identity."""
    delta_peak: float
    peak_a: float
    peak_b: float
    by_class: Dict[str, float]
    gained: List[LiveTensor]
    lost: List[LiveTensor]
    terms: Dict[str, List[float]] = field(repr=False, default_factory=dict)

    def total(self) -> float:
        return math.fsum(t for ts in self.terms.values() for t in ts)

    def identity_ok(self) -> bool:
        return self.total() == self.delta_peak

    def table(self, top: int = 6) -> str:
        lines = [f"peak delta {self.delta_peak:+.6e} B "
                 f"({self.peak_a:.6e} -> {self.peak_b:.6e}, b - a):"]
        for c, v in sorted(self.by_class.items(), key=lambda kv: -abs(kv[1])):
            lines.append(f"  {c:<14} {v:+12.6e} B")
        if self.gained:
            lines.append("largest tensors live only at b's peak:")
            for t in self.gained[:top]:
                lines.append(f"  + {t.name:<28} {t.cls:<12} {t.bytes:.3e} B")
        if self.lost:
            lines.append("largest tensors live only at a's peak:")
            for t in self.lost[:top]:
                lines.append(f"  - {t.name:<28} {t.cls:<12} {t.bytes:.3e} B")
        return "\n".join(lines)


def memory_diff(a, b, graph_a=None, graph_b=None) -> MemoryDiff:
    """Attribute the peak-occupancy difference between two simulated
    configs (``b`` minus ``a``, peak ranks) to memory classes.  Accepts
    results or ready-made ``MemoryTimeline``s."""
    ta = a if isinstance(a, MemoryTimeline) else memory_timeline(a, graph_a)
    tb = b if isinstance(b, MemoryTimeline) else memory_timeline(b, graph_b)
    ra, rb = ta.ranks[ta.peak_rank], tb.ranks[tb.peak_rank]
    terms_a, terms_b = _peak_terms(ra), _peak_terms(rb)
    keys = sorted(set(terms_a) | set(terms_b))
    terms = {c: list(terms_b.get(c, ())) + [-t for t in terms_a.get(c, ())]
             for c in keys}
    ba = memory_blame(ta, graph_a)
    bb = memory_blame(tb, graph_b)
    key = lambda t: (t.nid, t.cls)
    in_a = {key(t) for t in ba.tensors}
    in_b = {key(t) for t in bb.tensors}
    gained = [t for t in bb.tensors if key(t) not in in_a]
    lost = [t for t in ba.tensors if key(t) not in in_b]
    return MemoryDiff(delta_peak=rb.peak_bytes - ra.peak_bytes,
                      peak_a=ra.peak_bytes, peak_b=rb.peak_bytes,
                      by_class={c: math.fsum(ts) for c, ts in terms.items()},
                      gained=gained, lost=lost, terms=terms)


# -------------------------------------------------- Chrome counter tracks

def memory_counters(result, graph=None, scale: float = 1e6,
                    timeline: Optional[MemoryTimeline] = None) -> List[Dict]:
    """Per-rank occupancy counter tracks (Chrome ``C`` events): one
    ``memory_bytes`` track per rank whose stacked series are the memory
    classes — append to a ``to_chrome_trace`` event list or use
    ``export_memory_trace``."""
    tl = timeline or memory_timeline(result, graph)
    events: List[Dict] = []
    for r in sorted(tl.ranks):
        rm = tl.ranks[r]
        classes = sorted(rm.by_class)
        for i, t in enumerate(rm.times):
            events.append({"ph": "C", "pid": r, "name": "memory_bytes",
                           "ts": t * scale,
                           "args": {c: rm.by_class[c][i] for c in classes}})
    return events


def export_memory_trace(result, path: str, graph=None,
                        meta: Optional[Dict] = None) -> Dict:
    """Chrome trace of the simulated timeline *plus* per-rank occupancy
    counter tracks (process metadata stays sorted with
    ``process_sort_index``, as ``to_chrome_trace`` emits it); returns
    the trace dict."""
    import json as _json
    from repro.trace.export import to_chrome_trace
    trace = to_chrome_trace(result, graph, meta)
    trace["traceEvents"].extend(memory_counters(result, graph))
    with open(path, "w") as f:
        _json.dump(trace, f)
        f.write("\n")
    return trace
