"""Self-instrumentation: spans / counters / gauges for the whole stack.

Recording is OFF by default.  Every primitive loads one module global and
early-returns when it is ``None``, so instrumented hot paths pay a few
tens of nanoseconds per call site when disabled (gated <3% of a 10k-node
``simulate`` by BENCH_obs.json).  Instrumentation therefore sits at
per-*call* granularity — one span per compile / engine run / trial —
never inside the per-node event loop.

Fork-safety: a forked ``core.pool`` worker inherits the parent's live
recorder.  ``fork_child_begin`` swaps in a fresh one so the child
measures only its own chunk; ``fork_child_payload`` packs
``(pid, counters, spans, ...)`` onto the pool's result tuples and
``merge_child`` folds it back into the parent recorder — counters are
additive, so a pooled sweep reports the same totals as a serial one
(property-tested in tests/test_obs.py).  Timestamps are
``time.perf_counter`` (CLOCK_MONOTONIC on Linux), comparable across the
fork boundary.

Pool/worker statistics live *outside* ``counters`` (in ``workers`` /
``pool``) precisely so the serial-vs-pooled counter identity holds.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

SPAN_CAP = 100_000        # spans kept per recorder; overflow counted, dropped
METRICS_SCHEMA = "flint-obs-v1"


class Recorder:
    """One recording session.

    ``counters``  name -> accumulated float (additive across workers)
    ``gauges``    name -> last-set float
    ``spans``     (name, start_s, end_s, pid) tuples, perf_counter clock
    ``workers``   pid -> {"busy_s", "items", "chunks"} from pool children
    ``pool``      aggregate pool stats: wall_s / capacity_s / busy_s
    ``n_events``  total primitive invocations (used by the overhead bench)
    """

    def __init__(self, span_cap: int = SPAN_CAP):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.spans: List[Tuple[str, float, float, int]] = []
        self.workers: Dict[int, Dict[str, float]] = {}
        self.pool: Dict[str, float] = {}
        self.span_cap = int(span_cap)
        self.dropped_spans = 0
        self.n_events = 0
        self.t0 = time.perf_counter()
        self._lock = threading.Lock()


_recorder: Optional[Recorder] = None


def enable(span_cap: int = SPAN_CAP) -> Recorder:
    """Start recording (idempotent: replaces any live recorder)."""
    global _recorder
    _recorder = Recorder(span_cap=span_cap)
    return _recorder


def disable() -> Optional[Recorder]:
    """Stop recording; returns the recorder that was live (or None)."""
    global _recorder
    r, _recorder = _recorder, None
    return r


def recording() -> bool:
    return _recorder is not None


def current() -> Optional[Recorder]:
    return _recorder


def counter(name: str, inc: float = 1.0) -> None:
    """Add ``inc`` to a named counter.  No-op unless recording."""
    r = _recorder
    if r is None:
        return
    with r._lock:
        r.n_events += 1
        r.counters[name] = r.counters.get(name, 0.0) + inc


def gauge(name: str, value: float) -> None:
    """Set a named gauge to its latest value.  No-op unless recording."""
    r = _recorder
    if r is None:
        return
    with r._lock:
        r.n_events += 1
        r.gauges[name] = float(value)


class _NullSpan:
    """Shared no-op context manager returned while disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "rec", "t0")

    def __init__(self, name: str, rec: Recorder):
        self.name = name
        self.rec = rec

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter()
        rec = self.rec
        with rec._lock:
            rec.n_events += 1
            if len(rec.spans) < rec.span_cap:
                rec.spans.append((self.name, self.t0, end, os.getpid()))
            else:
                rec.dropped_spans += 1
        return False


def span(name: str):
    """``with obs.span("compile.graph"): ...`` — times the block when
    recording, otherwise returns a shared no-op context manager."""
    r = _recorder
    if r is None:
        return _NULL_SPAN
    return _Span(name, r)


# ---------------------------------------------------------------- fork glue

def fork_child_begin() -> Optional[Recorder]:
    """Called in a forked pool worker before running a chunk.  If the
    inherited recorder is live, swap in a fresh one (so the child records
    only its own work) and return it; else return None."""
    global _recorder
    if _recorder is None:
        return None
    _recorder = Recorder(span_cap=_recorder.span_cap)
    return _recorder


def fork_child_payload(rec: Recorder, busy_s: float, items: int):
    """Picklable summary of a worker-chunk recorder, shipped to the parent
    on the pool result tuple."""
    return (os.getpid(), dict(rec.counters), dict(rec.gauges),
            list(rec.spans), rec.dropped_spans, rec.n_events,
            float(busy_s), int(items))


def merge_child(payload) -> None:
    """In the parent: fold one worker payload into the live recorder."""
    r = _recorder
    if r is None or payload is None:
        return
    pid, counters, gauges, spans, dropped, n_events, busy_s, items = payload
    with r._lock:
        r.n_events += n_events
        for k, v in counters.items():
            r.counters[k] = r.counters.get(k, 0.0) + v
        r.gauges.update(gauges)
        room = r.span_cap - len(r.spans)
        if room > 0:
            r.spans.extend(spans[:room])
        r.dropped_spans += dropped + max(0, len(spans) - max(0, room))
        w = r.workers.setdefault(pid, {"busy_s": 0.0, "items": 0,
                                       "chunks": 0})
        w["busy_s"] += busy_s
        w["items"] += items
        w["chunks"] += 1


def pool_stats(wall_s: float, workers: int) -> None:
    """Record one ``map_fork`` pool section (parent side)."""
    r = _recorder
    if r is None:
        return
    with r._lock:
        r.pool["sections"] = r.pool.get("sections", 0.0) + 1.0
        r.pool["wall_s"] = r.pool.get("wall_s", 0.0) + wall_s
        r.pool["capacity_s"] = (r.pool.get("capacity_s", 0.0)
                                + wall_s * workers)


# ---------------------------------------------------------------- export

def span_summary(rec: Optional[Recorder] = None) -> Dict[str, Dict[str, float]]:
    """Aggregate spans by name: {name: {n, total_s, max_s}}."""
    r = rec if rec is not None else _recorder
    out: Dict[str, Dict[str, float]] = {}
    if r is None:
        return out
    for name, start, end, _pid in r.spans:
        d = end - start
        s = out.setdefault(name, {"n": 0, "total_s": 0.0, "max_s": 0.0})
        s["n"] += 1
        s["total_s"] += d
        if d > s["max_s"]:
            s["max_s"] = d
    return out


def hit_rates(counters: Dict[str, float]) -> Dict[str, Dict[str, float]]:
    """Pair up ``<base>.hit`` / ``<base>.miss`` counters into rates."""
    out: Dict[str, Dict[str, float]] = {}
    for name, v in counters.items():
        for suf in (".hit", ".miss"):
            if name.endswith(suf):
                base = name[:-len(suf)]
                out.setdefault(base, {"hit": 0.0, "miss": 0.0})[suf[1:]] = v
    for base, hm in out.items():
        tot = hm["hit"] + hm["miss"]
        hm["rate"] = hm["hit"] / tot if tot else 0.0
    return out


def metrics_dict(rec: Optional[Recorder] = None) -> dict:
    """JSON-ready snapshot of a recorder (the ``repro.obs report`` input)."""
    r = rec if rec is not None else _recorder
    if r is None:
        raise ValueError("no recorder: call obs.enable() first")
    busy = sum(w["busy_s"] for w in r.workers.values())
    pool = dict(r.pool)
    if pool.get("capacity_s"):
        pool["busy_s"] = busy
        pool["utilization"] = busy / pool["capacity_s"]
    return {"schema": METRICS_SCHEMA,
            "wall_s": time.perf_counter() - r.t0,
            "counters": dict(sorted(r.counters.items())),
            "gauges": dict(sorted(r.gauges.items())),
            "hit_rates": hit_rates(r.counters),
            "spans": {"n": len(r.spans), "dropped": r.dropped_spans,
                      "by_name": span_summary(r)},
            "workers": {str(pid): dict(w)
                        for pid, w in sorted(r.workers.items())},
            "pool": pool,
            "n_events": r.n_events}


def dump_metrics(path: str, rec: Optional[Recorder] = None) -> str:
    """Write ``metrics_dict`` as JSON; returns the path."""
    with open(path, "w") as f:
        json.dump(metrics_dict(rec), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def dump_trace(path: str, rec: Optional[Recorder] = None) -> str:
    """Write the recorder's self-spans as Chrome trace JSON (the same
    schema trace/export.py emits for simulated timelines)."""
    from repro.trace.export import obs_chrome_trace
    r = rec if rec is not None else _recorder
    if r is None:
        raise ValueError("no recorder: call obs.enable() first")
    with open(path, "w") as f:
        json.dump(obs_chrome_trace(r), f, indent=2)
        f.write("\n")
    return path
