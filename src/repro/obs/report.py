"""``python -m repro.obs report`` — summarize a recorded run.

Reads a metrics JSON written by ``obs.dump_metrics`` (e.g. via
``python -m repro.search run --obs metrics.json``) and prints the things
one actually asks of a sweep: where wall-clock went (top spans), how the
caches did (hit rates), how busy the pool workers were (utilization),
and the raw counters.
"""
from __future__ import annotations

import argparse
import json
from typing import List


def render_memory(metrics: dict) -> str:
    """Memory section: per-rank peak occupancy gauges published by
    ``obs.memory.memory_timeline`` (``memory.rank<r>.peak_bytes``), plus
    HBM utilization and time-above-90%-capacity when the run recorded a
    capacity (``hbm_bytes`` set)."""
    gauges = metrics.get("gauges", {})
    ranks = {}
    for name, v in gauges.items():
        if not name.startswith("memory.rank"):
            continue
        rank_part, _, metric = name[len("memory."):].partition(".")
        try:
            r = int(rank_part[len("rank"):])
        except ValueError:
            continue
        ranks.setdefault(r, {})[metric] = v
    if not ranks:
        return ("memory: no memory.rank*.peak_bytes gauges in this "
                "metrics file (record a run that calls "
                "obs.memory.memory_timeline)")
    lines = [f"memory occupancy ({len(ranks)} ranks):"]
    for r in sorted(ranks):
        g = ranks[r]
        pk = g.get("peak_bytes", 0.0)
        line = f"  rank {r:<4} peak {pk:>12.6e} B"
        cap = g.get("hbm_bytes")
        if cap:
            line += (f"  {pk / cap:6.1%} of HBM"
                     f"  >90% for {g.get('time_at_90pct', 0.0):.3e} s")
        lines.append(line)
    return "\n".join(lines)


def render(metrics: dict, top: int = 12) -> str:
    """Human-readable report of one ``metrics_dict`` snapshot."""
    lines: List[str] = []
    wall = metrics.get("wall_s", 0.0)
    lines.append(f"obs report — wall {wall:.3f} s, "
                 f"{int(metrics.get('n_events', 0))} events recorded")

    spans = metrics.get("spans", {})
    by_name = spans.get("by_name", {})
    if by_name:
        lines.append("")
        lines.append(f"top spans by total time ({spans.get('n', 0)} spans"
                     + (f", {spans['dropped']} dropped"
                        if spans.get("dropped") else "") + "):")
        lines.append(f"  {'span':<28} {'n':>6} {'total_s':>10} "
                     f"{'mean_ms':>9} {'max_ms':>9}")
        ranked = sorted(by_name.items(),
                        key=lambda kv: -kv[1]["total_s"])[:top]
        for name, s in ranked:
            mean_ms = s["total_s"] / s["n"] * 1e3 if s["n"] else 0.0
            lines.append(f"  {name:<28} {int(s['n']):>6} "
                         f"{s['total_s']:>10.4f} {mean_ms:>9.3f} "
                         f"{s['max_s'] * 1e3:>9.3f}")

    rates = metrics.get("hit_rates", {})
    if rates:
        lines.append("")
        lines.append("cache hit rates:")
        for base, hm in sorted(rates.items()):
            tot = hm["hit"] + hm["miss"]
            lines.append(f"  {base:<28} {hm['rate']:>7.1%}  "
                         f"({int(hm['hit'])}/{int(tot)})")

    pool = metrics.get("pool", {})
    workers = metrics.get("workers", {})
    if pool or workers:
        lines.append("")
        util = pool.get("utilization")
        head = "pool utilization:"
        if util is not None:
            head += (f" {util:.1%} of {pool.get('capacity_s', 0.0):.3f} "
                     f"worker-seconds "
                     f"({int(pool.get('sections', 0))} sections)")
        lines.append(head)
        for pid, w in sorted(workers.items()):
            lines.append(f"  worker {pid:<8} busy {w['busy_s']:>8.4f} s  "
                         f"items {int(w['items']):>5}  "
                         f"chunks {int(w['chunks']):>4}")

    counters = metrics.get("counters", {})
    if counters:
        lines.append("")
        lines.append("counters:")
        for name, v in sorted(counters.items()):
            lines.append(f"  {name:<32} {v:g}")
    gauges = metrics.get("gauges", {})
    if gauges:
        lines.append("gauges:")
        for name, v in sorted(gauges.items()):
            lines.append(f"  {name:<32} {v:g}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Observability tooling (see repro.obs).")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="summarize a metrics JSON")
    rp.add_argument("metrics", help="path written by obs.dump_metrics / "
                    "search run --obs")
    rp.add_argument("--top", type=int, default=12,
                    help="span rows to show (default 12)")
    rp.add_argument("--memory", action="store_true",
                    help="append the per-rank memory-occupancy section "
                         "(memory.rank*.peak_bytes gauges)")
    args = ap.parse_args(argv)
    if args.cmd == "report":
        with open(args.metrics) as f:
            metrics = json.load(f)
        print(render(metrics, top=args.top))
        if args.memory:
            print()
            print(render_memory(metrics))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
