"""Critical-path "explain" attribution for simulated runs.

Answers *why* a simulated step takes as long as it does.  Two products:

Blame decomposition
    Every instant of ``[0, makespan]`` on every rank is charged to exactly
    one of four components — ``compute_busy`` (compute-class work running),
    ``exposed_comm`` (communication cost not hidden by compute),
    ``barrier_wait`` (arrived at a cross-rank collective, blocked on a
    straggler — the engine records per-span wait, see ``Span.wait``), or
    ``stall`` (nothing running: dependency gaps, early-finish tail,
    fault-induced idle).  The partition is *bit-exact*: interval lengths
    are kept as exact two-float (Knuth TwoSum) term pairs and summed with
    ``math.fsum``, so the components provably sum to the makespan to the
    last ulp (``identity_ok``; property-tested on randomized DAGs and MPMD
    programs).  The same terms re-keyed by node class (``all-gather``,
    ``p2p``, ``compute``, ...) give per-op-class blame.

Critical path
    A best-effort longest chain walked back from the last-finishing span —
    each step jumps to the latest-ending span that gated the current one
    (same-rank predecessor, or the gating rank across a collective
    barrier).  Diagnostic, not part of the bit-exact contract.

``explain_diff(a, b)`` attributes a step-time delta between two configs to
components, node classes and ranks — the "why" behind a DSE Pareto point.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import chakra
from repro.core.costmodel.simulator import (ClusterSimResult, SimResult,
                                            Span)

COMPONENTS = ("compute_busy", "exposed_comm", "barrier_wait", "bubble",
              "stall")
_COMM_TYPES = (chakra.COMM_COLL, chakra.COMM_SEND, chakra.COMM_RECV)
STALL_CLASS = "(stall)"


def _two_diff(b: float, a: float) -> Tuple[float, float]:
    """(d, e) with ``d + e == b - a`` exactly (TwoSum on (b, -a))."""
    y = -a
    s = b + y
    bv = s - b
    return s, (b - (s - bv)) + (y - bv)


def node_class(graph: Optional[chakra.Graph], nid: int,
               stream: str) -> str:
    """Attribution class of one span: collective kind / p2p / compute /
    mem when the graph is known, else the stream name."""
    if graph is None:
        return stream
    n = graph.node(nid)
    if n.type == chakra.COMP:
        return "compute"
    if n.type == chakra.COMM_COLL:
        return n.attrs.get("comm_kind", "collective")
    if n.type in (chakra.COMM_SEND, chakra.COMM_RECV):
        return "p2p"
    return n.type                      # "MEM" etc.


@dataclass
class RankBlame:
    """Blame decomposition of one rank over ``[0, makespan]``.

    ``components[c]`` / ``by_class[k]`` are ``math.fsum`` reductions of
    exact interval terms; ``total()`` re-sums every term in one pass, so
    ``identity_ok()`` (``total() == makespan``) is the bit-exact contract.
    """
    rank: int
    makespan: float
    components: Dict[str, float]
    by_class: Dict[str, float]
    terms: Dict[str, List[float]] = field(repr=False, default_factory=dict)

    @property
    def compute_busy(self) -> float:
        return self.components["compute_busy"]

    @property
    def exposed_comm(self) -> float:
        return self.components["exposed_comm"]

    @property
    def barrier_wait(self) -> float:
        return self.components["barrier_wait"]

    @property
    def bubble(self) -> float:
        """Wait time on p2p channels — the pipeline fill/drain bubble,
        split out of ``barrier_wait`` (needs the graph; graph-free blames
        keep p2p waits under ``barrier_wait``)."""
        return self.components["bubble"]

    @property
    def stall(self) -> float:
        return self.components["stall"]

    def total(self) -> float:
        return math.fsum(t for ts in self.terms.values() for t in ts)

    def identity_ok(self) -> bool:
        return self.total() == self.makespan

    def fractions(self) -> Dict[str, float]:
        m = self.makespan
        return {c: (v / m if m else 0.0) for c, v in self.components.items()}


def _portions(spans: List[Span], graph: Optional[chakra.Graph],
              makespan: float):
    """Split spans into labeled portions (a, b, kind, nid, stream) with
    kind in {"comp", "cost", "wait"}, clipped to [0, makespan]."""
    out = []
    for s in spans:
        if graph is not None:
            is_comm = graph.node(s.nid).type in _COMM_TYPES
        else:
            is_comm = s.stream == "comm"
        wait = getattr(s, "wait", 0.0)
        if is_comm and wait > 0.0:
            mid = min(s.start + wait, s.end)
            out.append((s.start, mid, "wait", s.nid, s.stream))
            out.append((mid, s.end, "cost", s.nid, s.stream))
        elif is_comm:
            out.append((s.start, s.end, "cost", s.nid, s.stream))
        else:
            out.append((s.start, s.end, "comp", s.nid, s.stream))
    clipped = []
    for a, b, kind, nid, stream in out:
        a, b = max(0.0, a), min(makespan, b)
        if b > a:
            clipped.append((a, b, kind, nid, stream))
    return clipped


_KIND_TO_COMPONENT = {"comp": "compute_busy", "cost": "exposed_comm",
                      "wait": "barrier_wait"}


def blame(spans: List[Span], makespan: float,
          graph: Optional[chakra.Graph] = None, rank: int = 0) -> RankBlame:
    """Decompose one rank's timeline over ``[0, makespan]``.

    Sweep over the elementary intervals induced by all span boundaries;
    each interval is charged by priority compute > comm cost > comm wait >
    stall (comm running under compute is *hidden*, hence not exposed).
    Interval lengths enter as exact TwoSum pairs so the reduction is
    bit-exact (see module docstring).
    """
    portions = _portions(spans, graph, makespan)
    events: List[Tuple[float, int, int]] = []   # (t, +1/-1, portion index)
    for i, (a, b, _k, _n, _s) in enumerate(portions):
        events.append((a, 1, i))
        events.append((b, -1, i))
    bounds = sorted({0.0, makespan} | {t for t, _d, _i in events})
    ev_at: Dict[float, List[Tuple[int, int]]] = {}
    for t, d, i in events:
        ev_at.setdefault(t, []).append((d, i))

    active: Dict[str, Dict[int, Tuple[int, str]]] = \
        {"comp": {}, "cost": {}, "wait": {}}
    comp_terms: Dict[str, List[float]] = {c: [] for c in COMPONENTS}
    class_terms: Dict[str, List[float]] = {}

    for j, a in enumerate(bounds):
        for d, i in ev_at.get(a, ()):
            _pa, _pb, kind, nid, stream = portions[i]
            if d > 0:
                active[kind][i] = (nid, stream)
            else:
                active[kind].pop(i, None)
        if j + 1 >= len(bounds):
            break
        b = bounds[j + 1]
        for kind in ("comp", "cost", "wait"):
            if active[kind]:
                comp = _KIND_TO_COMPONENT[kind]
                nid, stream = next(iter(active[kind].values()))
                cls = node_class(graph, nid, stream)
                if kind == "wait" and cls == "p2p":
                    comp = "bubble"     # pipeline fill/drain, not a
                break                   # collective barrier
        else:
            comp, cls = "stall", STALL_CLASS
        d, e = _two_diff(b, a)
        comp_terms[comp] += (d, e)
        class_terms.setdefault(cls, []).append(d)
        class_terms[cls].append(e)

    return RankBlame(
        rank=rank, makespan=makespan,
        components={c: math.fsum(ts) for c, ts in comp_terms.items()},
        by_class={k: math.fsum(ts) for k, ts in class_terms.items()},
        terms=comp_terms)


# ------------------------------------------------------------ critical path

@dataclass
class CPItem:
    """One hop of the (best-effort) critical path, chronological order."""
    rank: int
    nid: int
    name: str
    cls: str
    start: float
    end: float
    gap_before: float                  # idle between predecessor end and start
    note: str = ""


def _walk_rank(spans: List[Span], graph: Optional[chakra.Graph],
               rank: int, limit: int) -> List[CPItem]:
    """Longest chain ending at the last-finishing span of one rank."""
    if not spans:
        return []
    by_end = sorted(spans, key=lambda s: (s.end, s.start))
    cur = by_end[-1]
    path: List[CPItem] = []
    k = len(by_end) - 1
    while len(path) < limit:
        wait = getattr(cur, "wait", 0.0)
        note = f"barrier wait {wait:.3e}s" if wait > 0.0 else ""
        item = CPItem(rank=rank, nid=cur.nid, name=cur.name,
                      cls=node_class(graph, cur.nid, cur.stream),
                      start=cur.start, end=cur.end, gap_before=0.0,
                      note=note)
        path.append(item)
        if cur.start <= 0.0:
            break
        # `is cur` guard: a zero-duration span satisfies end <= own start
        # and would pick itself forever
        while k >= 0 and (by_end[k] is cur or by_end[k].end > cur.start):
            k -= 1
        if k < 0:
            break
        pred = by_end[k]
        item.gap_before = cur.start - pred.end
        cur = pred
    path.reverse()
    return path


def critical_path(result, graph=None, limit: int = 10_000) -> List[CPItem]:
    """Best-effort critical path of a timeline-carrying result.

    For clusters the walk starts on the slowest rank and hops to the
    barrier-gating rank (the participant that arrived last, i.e. whose
    matching collective span carries no wait) when it reaches a waited-on
    collective.  ``graph`` (Graph / MPMDProgram / {rank: Graph}) enriches
    hop classes."""
    from repro.trace.export import graph_for_rank
    if isinstance(result, SimResult):
        return _walk_rank(result.spans(), graph_for_rank(graph, 0), 0, limit)
    if not isinstance(result, ClusterSimResult):
        raise TypeError(f"expected SimResult or ClusterSimResult, "
                        f"got {type(result).__name__}")
    rank = result.slowest_rank
    path: List[CPItem] = []
    visited = set()
    while len(path) < limit and rank not in visited:
        visited.add(rank)
        seg = _walk_rank(result.rank_spans(rank),
                         graph_for_rank(graph, rank), rank,
                         limit - len(path))
        path = seg + path
        if not seg:
            break
        head = seg[0]
        if head.start <= 0.0 or "barrier" not in head.note:
            break
        # the gating rank arrived last: its matching span ends with ours
        # but carries zero wait
        gate = None
        for r in range(result.n_ranks):
            if r in visited:
                continue
            for sp in result.rank_spans(r):
                if (sp.stream == "comm" and sp.end == head.end
                        and getattr(sp, "wait", 0.0) == 0.0):
                    gate = r
                    break
            if gate is not None:
                break
        if gate is None:
            break
        rank = gate
    return path


# ------------------------------------------------------------- explanations

@dataclass
class Explanation:
    """Blame + critical path for one simulated result.  ``ranks`` maps
    rank id -> RankBlame over ``[0, makespan]`` (a plain ``SimResult`` is
    rank 0); every rank's components sum to the cluster makespan
    bit-exactly (the early-finish tail lands in its ``stall``)."""
    makespan: float
    ranks: Dict[int, RankBlame]
    critical_path: List[CPItem]
    slowest_rank: int = 0

    def blame(self, rank: Optional[int] = None) -> RankBlame:
        return self.ranks[self.slowest_rank if rank is None else rank]

    def identity_ok(self) -> bool:
        return all(b.identity_ok() for b in self.ranks.values())

    def by_class(self) -> Dict[str, float]:
        """Class blame in rank-seconds, summed over ranks."""
        out: Dict[str, float] = {}
        for b in self.ranks.values():
            for k, v in b.by_class.items():
                out[k] = out.get(k, 0.0) + v
        return out

    def components(self) -> Dict[str, float]:
        """Component blame averaged over ranks (sums to makespan up to
        the 1/n division; per-rank views are the bit-exact ones)."""
        n = len(self.ranks) or 1
        return {c: math.fsum(b.components[c] for b in self.ranks.values()) / n
                for c in COMPONENTS}

    def table(self) -> str:
        lines = [f"makespan {self.makespan:.6e} s   "
                 f"ranks {len(self.ranks)}   slowest rank {self.slowest_rank}",
                 "component blame (slowest rank | mean over ranks):"]
        slow = self.blame()
        mean = self.components()
        for c in COMPONENTS:
            fr = slow.components[c] / self.makespan if self.makespan else 0.0
            lines.append(f"  {c:<13} {slow.components[c]:>12.6e} s "
                         f"({fr:6.1%})   mean {mean[c]:>12.6e} s")
        lines.append("per-class blame (rank-seconds, all ranks):")
        for k, v in sorted(self.by_class().items(), key=lambda kv: -kv[1]):
            lines.append(f"  {k:<20} {v:>12.6e}")
        if self.critical_path:
            lines.append(f"critical path ({len(self.critical_path)} hops, "
                         "last 8 shown):")
            for it in self.critical_path[-8:]:
                gap = f" (+{it.gap_before:.2e}s gap)" if it.gap_before else ""
                note = f"  [{it.note}]" if it.note else ""
                lines.append(f"  r{it.rank} {it.name:<24} {it.cls:<12} "
                             f"{it.start:.3e}->{it.end:.3e}{gap}{note}")
        return "\n".join(lines)


def explain(result, graph=None, with_critical_path: bool = True
            ) -> Explanation:
    """Full attribution of a timeline-carrying ``SimResult`` /
    ``ClusterSimResult``.  ``graph`` may be the workload Graph, an
    ``MPMDProgram``, or a ``{rank: Graph}`` dict (MPMD runs)."""
    from repro.trace.export import graph_for_rank
    if isinstance(result, SimResult):
        m = result.total_time
        ranks = {0: blame(result.spans(), m, graph_for_rank(graph, 0), 0)}
        slowest = 0
    elif isinstance(result, ClusterSimResult):
        m = result.step_time
        ranks = {r: blame(result.rank_spans(r), m,
                          graph_for_rank(graph, r), r)
                 for r in range(result.n_ranks)}
        slowest = result.slowest_rank
    else:
        raise TypeError(f"expected SimResult or ClusterSimResult, "
                        f"got {type(result).__name__}")
    cp = critical_path(result, graph) if with_critical_path else []
    return Explanation(makespan=m, ranks=ranks, critical_path=cp,
                       slowest_rank=slowest)


# ---------------------------------------------------------------- explain_diff

@dataclass
class ExplainDiff:
    """Attribution of ``b.makespan - a.makespan`` between two configs.

    ``by_component`` / ``by_class`` are signed fsum reductions over both
    runs' slowest-rank terms, so ``total()`` equals ``delta_makespan``
    bit-exactly.  ``by_rank`` lists per-rank component deltas for ranks
    present in both runs."""
    delta_makespan: float
    by_component: Dict[str, float]
    by_class: Dict[str, float]
    by_rank: Dict[int, Dict[str, float]]
    terms: Dict[str, List[float]] = field(repr=False, default_factory=dict)

    def total(self) -> float:
        return math.fsum(t for ts in self.terms.values() for t in ts)

    def identity_ok(self) -> bool:
        return self.total() == self.delta_makespan

    def table(self) -> str:
        lines = [f"step-time delta {self.delta_makespan:+.6e} s "
                 "(b - a, slowest-rank attribution):"]
        for c in COMPONENTS:
            lines.append(f"  {c:<13} {self.by_component[c]:+12.6e} s")
        lines.append("by node class:")
        for k, v in sorted(self.by_class.items(),
                           key=lambda kv: -abs(kv[1])):
            lines.append(f"  {k:<20} {v:+12.6e} s")
        if len(self.by_rank) > 1:
            worst = sorted(self.by_rank.items(),
                           key=lambda kv: -abs(math.fsum(kv[1].values())))
            lines.append("largest per-rank shifts:")
            for r, comps in worst[:4]:
                tot = math.fsum(comps.values())
                lines.append(f"  rank {r:<5} {tot:+12.6e} s")
        return "\n".join(lines)


def explain_diff(a, b, graph_a=None, graph_b=None) -> ExplainDiff:
    """Attribute the step-time difference between two simulated configs
    (``b`` minus ``a``) to blame components, node classes and ranks.
    Accepts results or ready-made ``Explanation``s."""
    ea = a if isinstance(a, Explanation) else explain(
        a, graph_a, with_critical_path=False)
    eb = b if isinstance(b, Explanation) else explain(
        b, graph_b, with_critical_path=False)
    ba, bb = ea.blame(), eb.blame()
    terms = {c: list(bb.terms[c]) + [-t for t in ba.terms[c]]
             for c in COMPONENTS}
    by_component = {c: math.fsum(ts) for c, ts in terms.items()}
    keys = set(ba.by_class) | set(bb.by_class)
    by_class = {k: bb.by_class.get(k, 0.0) - ba.by_class.get(k, 0.0)
                for k in keys}
    by_rank = {r: {c: eb.ranks[r].components[c] - ea.ranks[r].components[c]
                   for c in COMPONENTS}
               for r in set(ea.ranks) & set(eb.ranks)}
    return ExplainDiff(delta_makespan=eb.makespan - ea.makespan,
                       by_component=by_component, by_class=by_class,
                       by_rank=by_rank, terms=terms)


# ------------------------------------------------- utilization counter tracks

def utilization_counters(result, scale: float = 1e6) -> List[Dict]:
    """Per-rank 0/1 utilization counter tracks (Chrome ``C`` events):
    ``util_compute`` / ``util_comm`` step to 1 while the stream is busy.
    Append to a ``to_chrome_trace`` event list or use
    ``export_explain_trace``."""
    from repro.trace.export import _merged, _per_rank_spans
    events: List[Dict] = []
    for rank, spans in _per_rank_spans(result):
        for stream, track in (("comp", "util_compute"), ("comm", "util_comm")):
            merged = _merged([(s.start, s.end) for s in spans
                              if s.stream == stream and s.end > s.start])
            for a, b in merged:
                events.append({"ph": "C", "pid": rank, "name": track,
                               "ts": a * scale, "args": {"busy": 1}})
                events.append({"ph": "C", "pid": rank, "name": track,
                               "ts": b * scale, "args": {"busy": 0}})
    return events


def export_explain_trace(result, path: str, graph=None,
                         meta: Optional[Dict] = None) -> Dict:
    """Chrome trace of the simulated timeline *plus* per-rank utilization
    counter tracks; returns the trace dict."""
    import json as _json
    from repro.trace.export import to_chrome_trace
    trace = to_chrome_trace(result, graph, meta)
    trace["traceEvents"].extend(utilization_counters(result))
    with open(path, "w") as f:
        _json.dump(trace, f)
        f.write("\n")
    return trace
