"""repro.obs — observability for the whole stack.

Side A (self-tracing): ``enable()`` a recorder, run anything —
simulate / explore / SearchRun / monte_carlo — and every instrumented
layer (compile, engine runs, result caches, delta replays, MPMD memo,
pool workers, search generations, fault segments) emits counters and
spans; ``dump_metrics`` / ``dump_trace`` export them and
``python -m repro.obs report`` summarizes.  All primitives are no-ops
(one global load) while disabled.

Side B (workload attribution): ``repro.obs.explain`` decomposes a
simulated timeline into compute / exposed-comm / barrier-wait / stall
blame that sums to the makespan bit-exactly, walks the critical path,
and ``explain_diff`` attributes a step-time delta between two configs.
``repro.obs.memory`` is the bytes-axis counterpart: schedule-resolved
per-rank occupancy curves with a bit-exact class decomposition,
``memory_blame`` (live tensors at the peak) and ``memory_diff``
(peak-delta attribution between configs).  Import the functions from
the submodules (the package keeps import-time dependencies minimal so
the instrumented core can import it):

    from repro.obs.explain import explain, explain_diff
    from repro.obs.memory import memory_timeline, memory_blame
"""
from repro.obs.record import (Recorder, counter, current, disable,
                              dump_metrics, dump_trace, enable, gauge,
                              hit_rates, merge_child, metrics_dict,
                              recording, span, span_summary)

__all__ = ["Recorder", "counter", "current", "disable", "dump_metrics",
           "dump_trace", "enable", "gauge", "hit_rates", "merge_child",
           "metrics_dict", "recording", "span", "span_summary",
           "explain_diff", "explain_result", "explain_cluster",
           "memory_timeline", "memory_blame", "memory_diff"]

_EXPLAIN_NAMES = {"explain_diff", "explain_result", "explain_cluster",
                  "critical_path", "utilization_counters",
                  "export_explain_trace"}

_MEMORY_NAMES = {"memory_timeline", "memory_blame", "memory_diff",
                 "memory_counters", "export_memory_trace"}


def __getattr__(name):
    # lazy: repro.obs.explain / repro.obs.memory import the simulator,
    # which imports this package for its counters — eager import would
    # be a cycle
    if name in _EXPLAIN_NAMES:
        from repro.obs import explain as _explain
        if name in ("explain_result", "explain_cluster"):
            return _explain.explain
        return getattr(_explain, name)
    if name in _MEMORY_NAMES:
        from repro.obs import memory as _memory
        return getattr(_memory, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
