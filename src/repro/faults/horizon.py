"""Multi-step horizon simulation under a fault scenario.

The cluster engines (``simulate_cluster`` / ``simulate_mpmd``) price one
*steady-state* step.  This module stretches them over a horizon of many
steps during which the cluster changes out from under the job: a
``FaultScenario``'s events are applied as piecewise-constant rank/link
profiles, and the horizon is simulated segment by segment — one engine
evaluation per *distinct* profile signature, with repeated signatures
served from the engines' result memos (PR-5 ``run_rows`` + pool
coalescing underneath).  A 10k-step horizon with three slowdown windows
costs a handful of engine runs, not 10k.

Semantics (deliberately simple, documented over clever):

  * Steps are atomic; a step runs at the profile in force when it starts,
    so an event takes effect at the next step boundary after its time.
  * ``fail_stop`` rolls the job back to its last checkpoint (losing the
    steps since — the ``CheckpointPolicy`` cost model), then:
      - a spare rank, if provisioned, absorbs the failure: pay
        ``restore_cost`` and continue at K ranks (the repaired node
        rejoins the spare pool after its downtime);
      - otherwise an SPMD (single-graph) job *elastically rescales*: pay
        ``restore_cost`` and continue on the K-1 survivors (the engine
        reprices the step at the smaller cluster), paying another
        ``restore_cost`` to scale back up when the rank returns;
      - an MPMD program cannot drop a rank (its graph is part of the
        program), so the whole job stalls until the rank returns, then
        pays ``restore_cost``.
  * Checkpoints are written every ``policy.interval`` useful steps at
    ``policy.write_cost`` wall seconds; step 0 is checkpointed.
  * ``stall`` events add wall time with no progress.

Reported: **goodput** (useful-work seconds per wall second, 1.0 = ideal
fault-free cluster with free checkpoints), makespan inflation vs the
fault-free run of the same step count under the same checkpoint policy,
and the p50/p99 of executed step times.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro.core import chakra
from repro.core.costmodel.simulator import (_parse_rank_profiles,
                                            simulate_cluster)
from repro.core.costmodel.topology import RankProfile, Topology, build_topology
from repro.faults.scenario import CheckpointPolicy, FaultScenario
from repro.obs import record as obs

_INF = float("inf")


@dataclasses.dataclass
class HorizonResult:
    """Outcome of one horizon simulation (see module docstring)."""
    useful_steps: int
    wall_time: float
    goodput: float
    makespan_inflation: float
    nominal_step_time: float
    p50_step_time: float
    p99_step_time: float
    lost_steps: int
    lost_work_s: float
    checkpoint_s: float
    restore_s: float
    stall_s: float
    downtime_s: float
    n_failures: int
    n_checkpoints: int
    n_segments: int
    n_signatures: int
    # worst per-survivor memory-occupancy inflation over executed segments:
    # an elastic rescale to Kc survivors redistributes the failed ranks'
    # shards, inflating each survivor's occupancy by ~K/Kc (1.0 = never
    # rescaled).  Multiply the nominal schedule-aware ``peak_bytes`` by
    # this before checking an ``hbm_bytes`` capacity (see ``obs.memory``).
    survivor_mem_inflation: float = 1.0
    # (step_time, count) pairs of executed steps — Monte-Carlo pools these
    # across trials for aggregate percentiles
    step_records: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    # (t_start, t_end, step_time, steps) per contiguous same-rate segment
    segments: Optional[List[Tuple[float, float, float, int]]] = None

    def as_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
             if f.name not in ("step_records", "segments")}
        return d


def _weighted_pct(records: Dict[float, int], q: float) -> float:
    total = sum(records.values())
    if total == 0:
        return 0.0
    target = q * total
    cum = 0
    for s in sorted(records):
        cum += records[s]
        if cum >= target:
            return s
    return max(records)


def simulate_horizon(workload, system, scenario: FaultScenario,
                     policy: Optional[CheckpointPolicy] = None, *,
                     topo: Optional[Topology] = None,
                     n_ranks: Optional[int] = None,
                     n_steps: Optional[int] = None,
                     wall_limit: Optional[float] = None,
                     spare_ranks: int = 0,
                     rank_profiles=None,
                     algo: str = "auto", compute_derate: float = 0.6,
                     memoize: bool = True,
                     keep_segments: bool = False) -> HorizonResult:
    """Run `workload` for a horizon under `scenario` + `policy`.

    Stop condition: `n_steps` useful steps completed, or `wall_limit`
    seconds of wall clock consumed (whichever first; default
    ``wall_limit=scenario.horizon``).  `workload` is anything
    ``simulate_cluster`` accepts — a Graph (SPMD, supports elastic
    rescale) or an MPMD program/list/dict (fail-stops stall instead).
    `rank_profiles` are *static* per-rank profiles (a hetero cluster's
    baseline); fault windows compose multiplicatively on top of them.
    `memoize=False` forces a full engine rebuild per segment (the naive
    baseline the fault benchmark measures against)."""
    policy = policy or CheckpointPolicy()
    topo = topo or build_topology(system)
    is_graph = isinstance(workload, chakra.Graph)
    if is_graph:
        K = int(n_ranks if n_ranks is not None else topo.n_ranks)
    else:
        from repro.core.costmodel.mpmd import MPMDProgram
        if not isinstance(workload, MPMDProgram):
            workload = MPMDProgram(workload)
        K = workload.n_ranks
        if n_ranks is not None and int(n_ranks) != K:
            raise ValueError(f"n_ranks={n_ranks} disagrees with the MPMD "
                             f"program's {K} ranks")
    if scenario.n_ranks is not None and scenario.n_ranks != K:
        raise ValueError(f"scenario was sampled for {scenario.n_ranks} "
                         f"ranks, cluster has {K}")
    if n_steps is None and wall_limit is None:
        wall_limit = scenario.horizon
    if spare_ranks < 0:
        raise ValueError(f"spare_ranks must be >= 0, got {spare_ranks}")
    base_profs = _parse_rank_profiles(rank_profiles, K)

    sig_cache: Dict[tuple, float] = {}
    sigs_seen: set = set()          # distinct signatures, memoize or not

    def step_time(failed: frozenset, active: List[list]) -> float:
        # signature: surviving-cluster size + surviving effects remapped to
        # the survivors' dense rank ids (identical signatures — however the
        # timeline reached them — share one engine evaluation)
        if is_graph and failed:
            survivors = [r for r in range(K) if r not in failed]
            remap = {r: i for i, r in enumerate(survivors)}
            Kc = len(survivors)
        else:
            remap = None
            Kc = K
        eff = []
        for _, kind, rank, mag in active:
            if rank is None:
                continue
            if remap is not None:
                if rank in failed:
                    continue
                rank = remap[rank]
            if 0 <= rank < Kc:
                eff.append((rank, kind, mag))
        sig = (Kc, tuple(sorted(eff)))
        sigs_seen.add(sig)
        if memoize:
            hit = sig_cache.get(sig)
            if hit is not None:
                obs.counter("faults.memo_served")
                return hit
        obs.counter("faults.segment_sim")
        prof: Dict[int, RankProfile] = {}
        if base_profs:
            if remap is not None:
                prof = {remap[r]: p for r, p in base_profs.items()
                        if r in remap}
            else:
                prof = dict(base_profs)
        for rank, kind, mag in sig[1]:
            p = prof.get(rank, RankProfile())
            if kind == "slowdown":
                p = p.scaled(compute_scale=1.0 / mag)
            else:
                p = p.scaled(link_scale=mag)
            prof[rank] = p
        with obs.span("faults.segment_sim"):
            res = simulate_cluster(
                workload, system, topo, n_ranks=Kc if is_graph else None,
                rank_profiles=prof or None, algo=algo,
                compute_derate=compute_derate, memoize=memoize)
        s = float(res.total_time)
        if not s > 0.0:
            raise ValueError(f"non-positive step time {s} for signature {sig}")
        if memoize:
            sig_cache[sig] = s
        return s

    s0 = step_time(frozenset(), [])

    events = scenario.events
    ei = 0
    active: List[list] = []         # [end_time, kind, rank, magnitude]
    returns: List[tuple] = []       # heap of (time, tag, rank)
    failed: set = set()
    spares = int(spare_ranks)
    t = 0.0
    done = 0                        # useful (checkpoint-survivable) steps
    since = 0                       # steps since last checkpoint
    sec_since = 0.0
    records: Dict[float, int] = {}
    segments: List[list] = []       # [t0, t1, s, steps]
    lost_steps = 0
    lost_s = ckpt_s = restore_s = stall_s = downtime_s = 0.0
    n_fail = n_ckpt = 0
    mem_infl = 1.0

    guard = 0
    while True:
        guard += 1
        if guard > 10_000_000:
            raise RuntimeError("simulate_horizon failed to make progress "
                               f"(t={t}, done={done})")
        if n_steps is not None and done >= n_steps:
            break
        if wall_limit is not None and t >= wall_limit:
            break

        # apply everything due by now (rank returns first: a rank that came
        # back can absorb a failure arriving at the same instant)
        while returns and returns[0][0] <= t:
            _, tag, rank = heapq.heappop(returns)
            if tag == "spare":
                spares += 1
            else:
                failed.discard(rank)
                t += policy.restore_cost      # reintegration restore
                restore_s += policy.restore_cost
        while ei < len(events) and events[ei].time <= t:
            e = events[ei]
            ei += 1
            if e.kind == "stall":
                t += e.duration
                stall_s += e.duration
            elif e.kind in ("slowdown", "link_degrade"):
                active.append([e.time + e.duration, e.kind, e.rank,
                               e.magnitude])
            else:                             # fail_stop
                n_fail += 1
                lost_steps += since
                lost_s += sec_since
                done -= since
                since = 0
                sec_since = 0.0
                if spares > 0:
                    spares -= 1
                    t += policy.restore_cost
                    restore_s += policy.restore_cost
                    if e.duration > 0:        # repaired node rejoins pool
                        heapq.heappush(returns,
                                       (e.time + e.duration, "spare", e.rank))
                else:
                    failed.add(e.rank)
                    if e.duration > 0:
                        heapq.heappush(returns,
                                       (e.time + e.duration, "rank", e.rank))
                    if is_graph:              # elastic rescale to survivors
                        if len(failed) >= K:
                            raise ValueError("all ranks failed with no "
                                             "spares left")
                        t += policy.restore_cost
                        restore_s += policy.restore_cost
        if active and any(a[0] <= t for a in active):
            active = [a for a in active if a[0] > t]

        # next profile boundary
        nb = events[ei].time if ei < len(events) else _INF
        for a in active:
            if a[0] < nb:
                nb = a[0]
        if returns and returns[0][0] < nb:
            nb = returns[0][0]
        if wall_limit is not None and wall_limit < nb:
            nb = wall_limit

        if failed and not is_graph:
            # MPMD: the program needs every rank; stall until one returns
            if nb is _INF or nb == _INF:
                raise RuntimeError(
                    "MPMD program permanently stalled: a rank failed with "
                    "no spares, no scheduled return, and no wall_limit")
            downtime_s += nb - t
            t = nb
            continue

        if failed and is_graph:
            infl = K / float(K - len(failed))
            if infl > mem_infl:
                mem_infl = infl
        s = step_time(frozenset(failed), active)
        room = max(1, int((nb - t) / s)) if nb < _INF else _INF
        chunk = policy.interval - since
        if room < chunk:
            chunk = room
        if n_steps is not None and n_steps - done < chunk:
            chunk = n_steps - done
        if wall_limit is not None:
            fit = int((wall_limit - t) / s)
            if fit <= 0:                      # budget dies mid-step
                t = wall_limit
                break
            if fit < chunk:
                chunk = fit
        t0 = t
        t += chunk * s
        done += chunk
        since += chunk
        sec_since += chunk * s
        records[s] = records.get(s, 0) + chunk
        if segments and segments[-1][2] == s:
            segments[-1][1] = t
            segments[-1][3] += chunk
        else:
            segments.append([t0, t, s, chunk])
        if since >= policy.interval:
            t += policy.write_cost
            ckpt_s += policy.write_cost
            n_ckpt += 1
            since = 0
            sec_since = 0.0

    wall = t if wall_limit is None else min(t, wall_limit)
    goodput = (done * s0 / wall) if wall > 0 else (1.0 if done else 0.0)
    ff = done * s0 + (done // policy.interval) * policy.write_cost
    if ff > 0:
        inflation = wall / ff
    else:
        inflation = 1.0 if wall == 0 else _INF
    return HorizonResult(
        useful_steps=done, wall_time=wall, goodput=goodput,
        makespan_inflation=inflation, nominal_step_time=s0,
        p50_step_time=_weighted_pct(records, 0.50),
        p99_step_time=_weighted_pct(records, 0.99),
        lost_steps=lost_steps, lost_work_s=lost_s,
        checkpoint_s=ckpt_s, restore_s=restore_s, stall_s=stall_s,
        downtime_s=downtime_s, n_failures=n_fail, n_checkpoints=n_ckpt,
        n_segments=len(segments), n_signatures=len(sigs_seen),
        survivor_mem_inflation=mem_infl,
        step_records=sorted(records.items()),
        segments=[tuple(sg) for sg in segments] if keep_segments else None)
