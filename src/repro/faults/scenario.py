"""Fault scenarios: seeded timelines of failure events over a cluster.

A ``FaultScenario`` is a wall-clock timeline of ``FaultEvent``s applied to
a K-rank cluster.  The horizon simulator (``faults.horizon``) interprets
events as piecewise-constant rank/link profiles between event boundaries:

  * ``slowdown``      -- one rank computes ``magnitude``x slower for
                         ``duration`` seconds (thermal throttling, noisy
                         neighbor, degraded host)
  * ``link_degrade``  -- one rank's NIC/ICI bandwidth is multiplied by
                         ``magnitude`` (< 1) for ``duration`` seconds
                         (flapping NIC, degraded pod uplink)
  * ``fail_stop``     -- one rank is preempted: work since the last
                         checkpoint is lost, the cluster pays the
                         checkpoint-restore delay, and the rank is gone for
                         ``duration`` seconds (covered by a spare, or the
                         job rescales elastically to K-1 ranks)
  * ``stall``         -- a transient cluster-wide stall of ``duration``
                         seconds with no progress (collective timeout +
                         retry, network partition blip)

Timelines are either hand-written (``FaultScenario([...], horizon=...)``)
or sampled from exponential per-kind rates (``FaultScenario.sample``).
Sampling couples scenarios across rates: arrival times are a unit-rate
Poisson process scaled by 1/rate from a dedicated uniform substream, so
raising a rate compresses the *same* arrival sequence instead of drawing a
fresh one.  That coupling is what makes expected goodput provably monotone
in the rate knob (property-tested) rather than just monotone on average.

``CheckpointPolicy`` + ``young_daly_interval`` supply the checkpoint cost
model the horizon simulator charges on fail-stop events.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

EVENT_KINDS = ("slowdown", "link_degrade", "fail_stop", "stall")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault at wall-clock ``time`` (seconds since step 0).

    ``rank`` is the afflicted rank (None for cluster-wide ``stall``);
    ``duration`` is how long the effect lasts (for ``fail_stop``: the
    downtime before the rank rejoins — 0 means it never returns);
    ``magnitude`` is the kind-specific factor: slowdown factor (> 1 =
    slower) for ``slowdown``, bandwidth multiplier (< 1 = degraded) for
    ``link_degrade``, unused otherwise."""
    time: float
    kind: str
    rank: Optional[int] = None
    duration: float = 0.0
    magnitude: float = 1.0

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}: "
                             f"expected one of {EVENT_KINDS}")
        if self.time < 0.0:
            raise ValueError(f"event time must be >= 0, got {self.time}")
        if self.duration < 0.0:
            raise ValueError(f"duration must be >= 0, got {self.duration}")
        if self.kind in ("slowdown", "link_degrade", "fail_stop") \
                and self.rank is None:
            raise ValueError(f"{self.kind} event needs a target rank")
        if self.kind == "slowdown" and self.magnitude < 1.0:
            raise ValueError("slowdown magnitude is a slowdown factor "
                             f">= 1, got {self.magnitude}")
        if self.kind == "link_degrade" and not 0.0 < self.magnitude <= 1.0:
            raise ValueError("link_degrade magnitude is a bandwidth "
                             f"multiplier in (0, 1], got {self.magnitude}")


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Checkpoint cost model: write every ``interval`` useful steps at
    ``write_cost`` seconds per write; a fail-stop rolls back to the last
    checkpoint (losing the steps since) and pays ``restore_cost`` seconds
    to reload + reshard.  Step 0 counts as checkpointed."""
    interval: int = 32
    write_cost: float = 0.0
    restore_cost: float = 0.0

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if self.write_cost < 0.0 or self.restore_cost < 0.0:
            raise ValueError("checkpoint costs must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultRates:
    """Exponential-MTBF fault process: cluster-wide arrival rates (events
    per second; MTBF = 1/rate) plus the fixed per-event parameters.  A rate
    of 0 disables that kind."""
    fail_rate: float = 0.0
    fail_downtime: float = 0.0       # rank downtime after a fail-stop
    slowdown_rate: float = 0.0
    slowdown_factor: float = 2.0
    slowdown_duration: float = 1.0
    degrade_rate: float = 0.0
    degrade_scale: float = 0.5
    degrade_duration: float = 1.0
    stall_rate: float = 0.0
    stall_duration: float = 0.1


class FaultScenario:
    """A sorted, validated timeline of ``FaultEvent``s over ``horizon``
    seconds on an ``n_ranks`` cluster (n_ranks=None: rank bounds are the
    simulator's problem)."""

    def __init__(self, events: Sequence[FaultEvent], horizon: float,
                 n_ranks: Optional[int] = None):
        if horizon <= 0.0:
            raise ValueError(f"horizon must be > 0, got {horizon}")
        evs = sorted(events, key=lambda e: (e.time, e.kind, e.rank or 0))
        if n_ranks is not None:
            for e in evs:
                if e.rank is not None and not 0 <= e.rank < n_ranks:
                    raise ValueError(
                        f"event rank {e.rank} outside cluster 0..{n_ranks - 1}")
        self.events: List[FaultEvent] = evs
        self.horizon = float(horizon)
        self.n_ranks = n_ranks

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        kinds = {}
        for e in self.events:
            kinds[e.kind] = kinds.get(e.kind, 0) + 1
        return (f"FaultScenario(horizon={self.horizon:g}, "
                f"events={dict(sorted(kinds.items()))})")

    @staticmethod
    def sample(rates: FaultRates, horizon: float, n_ranks: int,
               seed=0) -> "FaultScenario":
        """Draw a seeded scenario from exponential per-kind arrival rates.

        Deterministic in (rates, horizon, n_ranks, seed).  Arrival times
        come from a unit-rate Poisson substream divided by the kind's rate
        (inverse-CDF coupling — see module docstring); target ranks come
        from a separate substream consumed in arrival order, so the i-th
        event of a kind hits the same rank at every rate."""
        events: List[FaultEvent] = []
        specs = (
            ("fail_stop", rates.fail_rate,
             dict(duration=rates.fail_downtime)),
            ("slowdown", rates.slowdown_rate,
             dict(duration=rates.slowdown_duration,
                  magnitude=rates.slowdown_factor)),
            ("link_degrade", rates.degrade_rate,
             dict(duration=rates.degrade_duration,
                  magnitude=rates.degrade_scale)),
            ("stall", rates.stall_rate,
             dict(duration=rates.stall_duration)),
        )
        for kind, rate, kw in specs:
            if rate <= 0.0:
                continue
            arr = _seed_rng(seed, kind, "arrivals")
            rnk = _seed_rng(seed, kind, "ranks")
            t = 0.0
            while True:
                # unit-rate exponential gap scaled by 1/rate: same uniforms
                # across rates => monotone arrival coupling
                t += -math.log(1.0 - arr.random()) / rate
                if t >= horizon:
                    break
                rank = None
                if kind != "stall":
                    rank = int(rnk.integers(n_ranks))
                events.append(FaultEvent(time=t, kind=kind, rank=rank, **kw))
        return FaultScenario(events, horizon=horizon, n_ranks=n_ranks)


def _seed_rng(seed, *salt) -> np.random.Generator:
    """Independent substream for (seed, salt...): ints pass through,
    strings hash via crc32 (mirrors search.strategies)."""
    parts = list(seed) if isinstance(seed, (tuple, list)) else [seed]
    key = [int(p) if not isinstance(p, str)
           else zlib.crc32(p.encode()) for p in [*parts, *salt]]
    return np.random.default_rng(key)


def young_daly_interval(write_cost: float, mtbf: float) -> float:
    """Young/Daly first-order optimal checkpoint period in *seconds*:
    tau_opt = sqrt(2 * C * MTBF).  Divide by the step time for the optimal
    ``CheckpointPolicy.interval`` in steps."""
    if write_cost <= 0.0 or mtbf <= 0.0:
        raise ValueError("young_daly_interval needs write_cost > 0 and "
                         f"mtbf > 0, got C={write_cost}, MTBF={mtbf}")
    return math.sqrt(2.0 * write_cost * mtbf)
