"""Seeded Monte-Carlo over fault scenarios -> DSE-ready fault objectives.

``monte_carlo`` samples N ``FaultScenario``s from exponential per-kind
rates (common seed; trial i uses substream (seed, i)) and runs the horizon
simulator on each, aggregating **expected goodput**, p50/p99 step time
under faults, makespan inflation and failure counts.  Scenario sampling is
rate-coupled (see ``faults.scenario``), so the aggregate is monotone
non-increasing in each rate knob — a property the DSE relies on and the
test suite enforces.

``fault_metrics`` adapts this for ``core.dse``: it reads the fault knobs
off a trial config (``checkpoint_interval``, ``fault_rate``,
``spare_ranks``, plus the optional ``fault_*``/``checkpoint_*_cost``
overrides), runs a small deterministic Monte-Carlo around the trial's
nominal result and wraps both in a ``FaultSimResult`` whose extra
attributes (``expected_goodput``, ``p99_step_time_under_faults``,
``makespan_inflation``) are directly usable as ``search.objectives``
entries.  ``analytic_fault_metrics`` is the event-loop-free proxy fidelity
(first-order Young/Daly closed form) for successive-halving rungs.

Provisioning normalization: ``expected_goodput`` is useful work per wall
second *per provisioned rank*, i.e. the raw cluster goodput times
K / (K + spare_ranks).  Without it, infinite spares would dominate every
Pareto front; with it, spares trade idle hardware against lost work.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import chakra
from repro.core.costmodel.simulator import simulate_cluster
from repro.core.costmodel.topology import Topology, build_topology
from repro.faults.horizon import (HorizonResult, _weighted_pct,
                                  simulate_horizon)
from repro.faults.scenario import CheckpointPolicy, FaultRates, FaultScenario

# trial-config knobs that switch a DSE trial onto the fault-aware path
FAULT_KNOBS = ("checkpoint_interval", "fault_rate", "spare_ranks")
# optional overrides riding along (defaults are derived from the nominal
# step time s0 so the knobs stay meaningful across workload scales)
FAULT_AUX_KNOBS = ("fault_downtime", "fault_trials", "fault_steps",
                   "fault_seed", "checkpoint_write_cost",
                   "checkpoint_restore_cost")

DEFAULT_INTERVAL = 25          # steps between checkpoints
DEFAULT_TRIALS = 8
DEFAULT_STEPS = 200            # useful steps per MC trial
DEFAULT_WRITE_STEPS = 2.0      # write_cost  = 2 x nominal step time
DEFAULT_RESTORE_STEPS = 4.0    # restore_cost = 4 x nominal step time
DEFAULT_DOWNTIME_STEPS = 100.0  # rank downtime = 100 x nominal step time


def has_fault_knobs(config: Dict) -> bool:
    return any(config.get(k) is not None for k in FAULT_KNOBS)


@dataclasses.dataclass
class MonteCarloResult:
    """Aggregate of ``n_trials`` seeded horizon simulations."""
    expected_goodput: float
    goodput_std: float
    worst_goodput: float
    expected_makespan_inflation: float
    p50_step_time: float
    p99_step_time: float
    mean_failures: float
    n_trials: int
    # worst survivor memory-occupancy inflation seen in any trial (elastic
    # rescale packs failed ranks' shards onto survivors, ~K/Kc; 1.0 = no
    # rescale happened) — multiply the nominal peak_bytes by this when
    # checking hbm_bytes capacity under faults
    max_survivor_mem_inflation: float = 1.0
    trials: Optional[List[HorizonResult]] = None

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self) if f.name != "trials"}


def monte_carlo(workload, system, rates: FaultRates,
                policy: CheckpointPolicy, *,
                topo: Optional[Topology] = None,
                n_ranks: Optional[int] = None,
                n_steps: Optional[int] = None,
                wall_limit: Optional[float] = None,
                spare_ranks: int = 0, n_trials: int = 16, seed: int = 0,
                scenarios: Optional[List[FaultScenario]] = None,
                horizon_slack: float = 4.0, rank_profiles=None,
                algo: str = "auto", compute_derate: float = 0.6,
                memoize: bool = True,
                keep_trials: bool = False,
                jobs: Optional[int] = None,
                progress: Optional[Callable[[Dict], None]] = None,
                progress_interval: float = 1.0) -> MonteCarloResult:
    """Expected fault metrics for `workload` under exponential `rates`.

    Deterministic in (inputs, seed): trial i samples its scenario with
    substream (seed, i).  Pass `scenarios` to pin the exact failure
    timelines instead (common-random-numbers across policy arms — the
    Young/Daly validation uses this so every checkpoint interval faces the
    same failures).  Engine-level memoization makes repeated signatures
    free *across* trials too: MC cost scales with distinct profile
    signatures, not trials x steps.

    `jobs=N` runs the horizon trials on a fork process pool
    (``repro.core.pool``); trials aggregate in index order, so results
    are bit-identical to serial.  Note the pool defeats cross-trial
    engine memoization (each worker warms its own), so it pays off when
    scenarios are signature-diverse — fail-stop-heavy rate mixes — and
    not when most trials share a handful of profile signatures."""
    topo = topo or build_topology(system)
    is_graph = isinstance(workload, chakra.Graph)
    if not is_graph:
        from repro.core.costmodel.mpmd import MPMDProgram
        if not isinstance(workload, MPMDProgram):
            # convert once so the program-level result memo persists
            workload = MPMDProgram(workload)
        K = workload.n_ranks
    else:
        K = int(n_ranks if n_ranks is not None else topo.n_ranks)
    if scenarios is not None:
        n_trials = len(scenarios)
    if n_trials < 1:
        raise ValueError(f"n_trials must be >= 1, got {n_trials}")
    if n_steps is None and wall_limit is None:
        raise ValueError("monte_carlo needs n_steps or wall_limit")

    horizon = wall_limit
    if scenarios is None and horizon is None:
        # sample over a horizon generously covering the target step count;
        # a makespan beyond it sees a fault-free tail (slightly optimistic,
        # bounded by horizon_slack)
        s0 = float(simulate_cluster(
            workload, system, topo, n_ranks=K if is_graph else None,
            rank_profiles=rank_profiles, algo=algo,
            compute_derate=compute_derate, memoize=memoize).total_time)
        overhead = (n_steps // policy.interval + 1) * policy.write_cost
        horizon = horizon_slack * (n_steps * s0 + overhead)

    def _trial(i: int) -> HorizonResult:
        sc = (scenarios[i] if scenarios is not None
              else FaultScenario.sample(rates, horizon, K, seed=(seed, i)))
        return simulate_horizon(
            workload, system, sc, policy, topo=topo,
            n_ranks=K if is_graph else None, n_steps=n_steps,
            wall_limit=wall_limit, spare_ranks=spare_ranks,
            rank_profiles=rank_profiles, algo=algo,
            compute_derate=compute_derate, memoize=memoize)

    # `progress` observes trial completion: called with
    # {"trials", "total", "elapsed", "done"}, rate-limited to one call per
    # `progress_interval` seconds plus a final done=True call
    t0 = time.monotonic()
    last_prog = t0

    def _tick(done_trials: int) -> None:
        nonlocal last_prog
        if progress is None:
            return
        now = time.monotonic()
        if now - last_prog >= progress_interval:
            last_prog = now
            progress({"trials": done_trials, "total": n_trials,
                      "elapsed": now - t0, "done": False})

    results: List[HorizonResult] = []
    if jobs is not None and jobs > 1:
        from repro.core import pool as _pool
        for i, (hr, err) in enumerate(_pool.map_fork(_trial, range(n_trials),
                                                     jobs=jobs)):
            if err is not None:
                raise RuntimeError(
                    f"monte_carlo trial {i} failed in worker: {err}")
            results.append(hr)
            _tick(len(results))
    else:
        for i in range(n_trials):
            results.append(_trial(i))
            _tick(len(results))
    if progress is not None:
        progress({"trials": len(results), "total": n_trials,
                  "elapsed": time.monotonic() - t0, "done": True})
    pooled: Dict[float, int] = {}
    for hr in results:
        for s, c in hr.step_records:
            pooled[s] = pooled.get(s, 0) + c

    gs = [hr.goodput for hr in results]
    mean = sum(gs) / len(gs)
    var = sum((g - mean) ** 2 for g in gs) / len(gs)
    infl = [hr.makespan_inflation for hr in results
            if math.isfinite(hr.makespan_inflation)]
    return MonteCarloResult(
        expected_goodput=mean, goodput_std=math.sqrt(var),
        worst_goodput=min(gs),
        expected_makespan_inflation=(sum(infl) / len(infl)) if infl
        else float("inf"),
        p50_step_time=_weighted_pct(pooled, 0.50),
        p99_step_time=_weighted_pct(pooled, 0.99),
        mean_failures=sum(hr.n_failures for hr in results) / len(results),
        n_trials=n_trials,
        max_survivor_mem_inflation=max(
            (hr.survivor_mem_inflation for hr in results), default=1.0),
        trials=results if keep_trials else None)


class FaultSimResult:
    """A nominal Sim/ClusterSimResult decorated with fault metrics.

    Delegates every unknown attribute to the wrapped nominal result, so a
    fault-aware trial still answers ``total_time`` / ``peak_bytes`` /
    ``exposed_comm`` — existing objectives keep working, and the new ones
    (``expected_goodput``, ``p99_step_time_under_faults``,
    ``makespan_inflation``) ride alongside."""

    def __init__(self, base, *, expected_goodput: float,
                 p99_step_time_under_faults: float,
                 makespan_inflation: float, goodput_std: float = 0.0,
                 fault_fidelity: str = "mc",
                 mc: Optional[MonteCarloResult] = None):
        self._base = base
        self.expected_goodput = expected_goodput
        self.p99_step_time_under_faults = p99_step_time_under_faults
        self.makespan_inflation = makespan_inflation
        self.goodput_std = goodput_std
        self.fault_fidelity = fault_fidelity
        self.mc = mc

    def __getattr__(self, name):
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._base, name)

    def as_dict(self) -> dict:
        d = dict(self._base.as_dict()) if hasattr(self._base, "as_dict") \
            else {}
        d.update(expected_goodput=self.expected_goodput,
                 p99_step_time_under_faults=self.p99_step_time_under_faults,
                 makespan_inflation=self.makespan_inflation,
                 goodput_std=self.goodput_std,
                 fault_fidelity=self.fault_fidelity)
        return d

    def __repr__(self) -> str:
        return (f"FaultSimResult(expected_goodput="
                f"{self.expected_goodput:.4f}, p99_step_time_under_faults="
                f"{self.p99_step_time_under_faults:.3e}, base={self._base!r})")


def _fault_params(config: Dict, s0: float) -> Tuple[CheckpointPolicy,
                                                    FaultRates, int, int,
                                                    int, int]:
    """(policy, rates, spares, trials, steps, seed) from a trial config;
    cost/downtime defaults scale with the nominal step time s0."""
    def _get(name, default):
        v = config.get(name)
        return default if v is None else v

    policy = CheckpointPolicy(
        interval=int(_get("checkpoint_interval", DEFAULT_INTERVAL)),
        write_cost=float(_get("checkpoint_write_cost",
                              DEFAULT_WRITE_STEPS * s0)),
        restore_cost=float(_get("checkpoint_restore_cost",
                                DEFAULT_RESTORE_STEPS * s0)))
    rates = FaultRates(
        fail_rate=float(_get("fault_rate", 0.0)),
        fail_downtime=float(_get("fault_downtime",
                                 DEFAULT_DOWNTIME_STEPS * s0)))
    return (policy, rates, int(_get("spare_ranks", 0)),
            int(_get("fault_trials", DEFAULT_TRIALS)),
            int(_get("fault_steps", DEFAULT_STEPS)),
            int(_get("fault_seed", 0)))


def fault_metrics(workload, system, topo, config: Dict, base, *,
                  n_ranks: Optional[int] = None, rank_profiles=None,
                  algo: str = "auto",
                  compute_derate: float = 0.6) -> FaultSimResult:
    """Full-fidelity fault decoration of a DSE trial: run the seeded MC
    around the trial's nominal result (`rank_profiles` = the trial's
    static hetero profiles; fault windows compose on top).  Deterministic
    in (config, seed knobs), so search replay and result memoization stay
    exact."""
    topo = topo or build_topology(system)
    s0 = float(base.total_time)
    policy, rates, spares, trials, steps, seed = _fault_params(config, s0)
    K = int(n_ranks if n_ranks is not None else topo.n_ranks)
    mc = monte_carlo(workload, system, rates, policy, topo=topo,
                     n_ranks=K if isinstance(workload, chakra.Graph)
                     else None,
                     n_steps=steps, spare_ranks=spares, n_trials=trials,
                     seed=seed, rank_profiles=rank_profiles, algo=algo,
                     compute_derate=compute_derate)
    util = K / float(K + spares)
    return FaultSimResult(
        base, expected_goodput=mc.expected_goodput * util,
        p99_step_time_under_faults=mc.p99_step_time,
        makespan_inflation=mc.expected_makespan_inflation,
        goodput_std=mc.goodput_std, fault_fidelity="mc", mc=mc)


def analytic_goodput(step_time: float, interval: int, write_cost: float,
                     restore_cost: float, fail_rate: float) -> float:
    """First-order closed form behind Young/Daly: with checkpoint period
    tau = interval * step_time, overhead ~= C/tau + lambda * (tau/2 + R);
    goodput = 1 / (1 + overhead).  Maximized at tau = sqrt(2 C / lambda) =
    ``young_daly_interval(C, 1/lambda)``."""
    tau = max(interval, 1) * step_time
    if tau <= 0.0:
        return 0.0
    overhead = write_cost / tau + fail_rate * (tau / 2.0 + restore_cost)
    return 1.0 / (1.0 + overhead)


def analytic_fault_metrics(base, config: Dict,
                           n_ranks: int) -> FaultSimResult:
    """Event-loop-free fault proxy for sub-full search fidelities: the
    Young/Daly closed form on the proxy result's step time.  Preserves the
    gross ordering of (interval, rate, spares) configs — all a
    successive-halving rung needs — at zero extra simulation cost."""
    s0 = float(base.total_time)
    policy, rates, spares, _, _, _ = _fault_params(config, s0)
    util = n_ranks / float(n_ranks + spares)
    g = analytic_goodput(s0, policy.interval, policy.write_cost,
                         policy.restore_cost, rates.fail_rate)
    return FaultSimResult(
        base, expected_goodput=g * util, p99_step_time_under_faults=s0,
        makespan_inflation=(1.0 / g) if g > 0 else float("inf"),
        fault_fidelity="analytic")
