"""Fault-scenario simulation over the cluster engines.

Time-varying failures (rank slowdowns, NIC degradation, fail-stop
preemptions with checkpoint/restore, transient stalls) applied as
piecewise-constant profiles to a multi-step horizon, plus a seeded
Monte-Carlo layer that turns them into DSE objectives.  See
``faults.scenario`` / ``faults.horizon`` / ``faults.montecarlo``.
"""
from repro.faults.horizon import HorizonResult, simulate_horizon
from repro.faults.montecarlo import (FAULT_KNOBS, FaultSimResult,
                                     MonteCarloResult, analytic_fault_metrics,
                                     analytic_goodput, fault_metrics,
                                     has_fault_knobs, monte_carlo)
from repro.faults.scenario import (CheckpointPolicy, FaultEvent, FaultRates,
                                   FaultScenario, young_daly_interval)

__all__ = [
    "CheckpointPolicy", "FaultEvent", "FaultRates", "FaultScenario",
    "FaultSimResult", "FAULT_KNOBS", "HorizonResult", "MonteCarloResult",
    "analytic_fault_metrics", "analytic_goodput", "fault_metrics",
    "has_fault_knobs", "monte_carlo", "simulate_horizon",
    "young_daly_interval",
]
